"""Golden-trace regression test for the serving loop.

Serves one fixed-seed agentic trace on a single collocated replica and
compares the resulting turn-record summary — per-turn token counts, the
GLOBAL finish ordering, and conversation pinning — against a checked-in
golden file. Scheduling or chunking refactors that silently reorder
finishes, drop turns, or un-pin conversations fail here even when every
per-turn parity test still passes.

The setup is chosen so the event order is fully deterministic despite the
engine measuring real wall time: ONE mixed-role replica (a single logical
clock serializes prefill and decode), zero tool latency, and arrivals
packed at the trace head (all conversations prefill before the first
decode chunk). Finish order within a chunk is then decided by per-slot
step counts alone, never by timing noise — nothing in the summary depends
on float timings or sampled token CONTENT, so the golden file is stable
across platforms and jax versions.

Regenerate after an INTENTIONAL contract change with:
  REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_golden_trace.py
and commit the diff (it IS the reviewable behavior change).
"""
import json
import os
from pathlib import Path

import jax
import pytest

from repro.configs import get_reduced
from repro.core import make_scheduler
from repro.engine import EngineServer, ReplicaEngine
from repro.models import build_model
from repro.traces import TraceConfig, generate_trace

GOLDEN = Path(__file__).parent / "golden" / "decode_golden_trace.json"

TRACE = TraceConfig(seed=7, first_input_median=40, first_input_sigma=0.3,
                    first_input_max=80, append_median=10, append_sigma=0.3,
                    append_max=20, output_median=6, output_sigma=0.8,
                    output_max=20, mean_turns=2.0, max_turns=3,
                    tool_mean_s=0.0)


def _serve_summary():
    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rep = ReplicaEngine(cfg, params, n_slots=8, max_ctx=256,
                        replica_id=0, role="mixed")
    srv = EngineServer(make_scheduler("conserve"), [rep],
                       decode_mode="fused", record_tokens=True)

    finish_order = []
    orig_finish = srv._finish_turn

    def spy(task, t):
        finish_order.append([task.conv.cid, task.turn_idx])
        return orig_finish(task, t)

    srv._finish_turn = spy
    # arrivals packed at the head (1ns apart): no prefill can finish
    # faster, so every conversation joins the decode queue before the
    # first chunk runs no matter how warm the jit caches are
    trace = generate_trace(5, 1e9, cfg=TRACE, arrival_process="saturation")
    recs = {r.cid: r for r in srv.serve(trace)}

    return {
        "finish_order": finish_order,
        "conversations": {
            str(cid): {
                "turn_output_tokens": [t.n_output_tokens
                                       for t in recs[cid].turns],
                "turn_order": [t.turn_idx for t in recs[cid].turns],
                # pinning: collocated ConServe must never move KV
                "n_kv_transfers": recs[cid].n_kv_transfers,
                "n_remote_turns": recs[cid].n_remote_turns,
            } for cid in sorted(recs)
        },
        # sampled_tokens includes the prefill token, so counts are
        # output_tokens + 1 per (cid, turn) — a length check that is
        # independent of model numerics
        "stream_lengths": {f"{cid}:{turn}": len(toks) for (cid, turn), toks
                           in sorted(srv.sampled_tokens.items())},
    }


def test_golden_trace_summary_matches():
    summary = _serve_summary()
    if os.environ.get("REGEN_GOLDEN"):
        GOLDEN.parent.mkdir(exist_ok=True)
        GOLDEN.write_text(json.dumps(summary, indent=1) + "\n")
        pytest.skip(f"regenerated {GOLDEN}")
    assert GOLDEN.exists(), (
        f"golden file missing: run REGEN_GOLDEN=1 pytest {__file__} "
        "and commit tests/golden/decode_golden_trace.json")
    golden = json.loads(GOLDEN.read_text())
    assert summary == golden, (
        "serving summary diverged from the golden trace — if this change "
        "is intentional, regenerate with REGEN_GOLDEN=1 and commit the "
        "golden diff")
