"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py),
executed in interpret mode on CPU (the kernels target TPU)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import flash_decode_attention
from repro.kernels.prefill_attention import flash_prefill_attention
from repro.kernels.rglru_kernel import rglru_pallas
from repro.kernels.rwkv6_kernel import wkv6_pallas

TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def tol(dt):
    return TOLS[jnp.bfloat16] if dt == jnp.bfloat16 else TOLS[jnp.float32]


def rand(key, shape, dtype, scale=0.6):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


@pytest.mark.parametrize("B,S,H,D", [(1, 128, 2, 64), (2, 256, 4, 64),
                                     (1, 512, 2, 128), (3, 128, 1, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 96])
def test_flash_prefill_sweep(key, B, S, H, D, dtype, window):
    ks = jax.random.split(key, 3)
    q, k, v = (rand(ks[i], (B, S, H, D), dtype) for i in range(3))
    want = ref.causal_attention_ref(q, k, v, window=window)
    got = flash_prefill_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), window=window, block_q=64,
        block_k=64).transpose(0, 2, 1, 3)
    err = jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32)))
    assert float(err) < tol(dtype), f"err={float(err)}"


@pytest.mark.parametrize("B,S,H,Hkv,D", [(2, 256, 8, 2, 64), (1, 512, 4, 4, 64),
                                         (4, 128, 16, 2, 32),
                                         (2, 1024, 8, 1, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(key, B, S, H, Hkv, D, dtype):
    ks = jax.random.split(key, 4)
    q = rand(ks[0], (B, H, D), dtype)
    k = rand(ks[1], (B, S, Hkv, D), dtype)
    v = rand(ks[2], (B, S, Hkv, D), dtype)
    lens = jax.random.randint(ks[3], (B,), 1, S + 1)
    want = ref.decode_attention_ref(q, k, v, lens)
    got = flash_decode_attention(q, k, v, lens, block_k=128)
    err = jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32)))
    assert float(err) < tol(dtype), f"err={float(err)}"


@pytest.mark.parametrize("B,S,H,hs", [(1, 64, 2, 16), (2, 128, 3, 16),
                                      (1, 256, 2, 32), (2, 64, 1, 64)])
@pytest.mark.parametrize("chunk", [16, 32])
def test_wkv6_sweep(key, B, S, H, hs, chunk):
    ks = jax.random.split(key, 6)
    r = rand(ks[0], (B, S, H, hs), jnp.float32, 0.5)
    k = rand(ks[1], (B, S, H, hs), jnp.float32, 0.5)
    v = rand(ks[2], (B, S, H, hs), jnp.float32, 0.5)
    logw = -jnp.exp(rand(ks[3], (B, S, H, hs), jnp.float32, 0.5))
    u = rand(ks[4], (H, hs), jnp.float32, 0.3)
    s0 = rand(ks[5], (B, H, hs, hs), jnp.float32, 0.2)
    y_ref, sT_ref = ref.wkv6_ref(r, k, v, logw, u, s0)
    y, sT = wkv6_pallas(r, k, v, logw, u, s0, chunk=chunk)
    assert float(jnp.max(jnp.abs(y - y_ref))) < 5e-5
    assert float(jnp.max(jnp.abs(sT - sT_ref))) < 5e-5


@pytest.mark.parametrize("B,S,W", [(1, 128, 64), (2, 256, 128), (1, 512, 32)])
@pytest.mark.parametrize("chunk,block_w", [(64, 32), (128, 64)])
def test_rglru_sweep(key, B, S, W, chunk, block_w):
    if chunk > S or block_w > W:
        pytest.skip("block exceeds dims")
    ks = jax.random.split(key, 3)
    la = -jnp.exp(rand(ks[0], (B, S, W), jnp.float32, 0.3))
    b = rand(ks[1], (B, S, W), jnp.float32, 0.5)
    h0 = rand(ks[2], (B, W), jnp.float32, 0.2)
    h_ref, hT_ref = ref.rglru_ref(la, b, h0)
    h, hT = rglru_pallas(la, b, h0, chunk=chunk, block_w=block_w)
    assert float(jnp.max(jnp.abs(h - h_ref))) < 1e-5
    assert float(jnp.max(jnp.abs(hT - hT_ref))) < 1e-5


def test_model_chunked_wkv_matches_kernel_oracle(key):
    """The model-side chunked WKV6 and the Pallas kernel agree with the
    step-recurrence oracle — three independent implementations."""
    from repro.models.recurrent import wkv6_chunked
    B, S, H, hs = 2, 96, 2, 16
    ks = jax.random.split(key, 6)
    r = rand(ks[0], (B, S, H, hs), jnp.float32, 0.5)
    k = rand(ks[1], (B, S, H, hs), jnp.float32, 0.5)
    v = rand(ks[2], (B, S, H, hs), jnp.float32, 0.5)
    logw = -jnp.exp(rand(ks[3], (B, S, H, hs), jnp.float32, 0.5))
    u = rand(ks[4], (H, hs), jnp.float32, 0.3)
    s0 = rand(ks[5], (B, H, hs, hs), jnp.float32, 0.2)
    y0, s0T = ref.wkv6_ref(r, k, v, logw, u, s0)
    y1, s1T = wkv6_pallas(r, k, v, logw, u, s0, chunk=32)
    y2, s2T = wkv6_chunked(r, k, v, logw, u, s0, chunk=24)  # uneven chunk
    assert float(jnp.max(jnp.abs(y1 - y0))) < 5e-5
    assert float(jnp.max(jnp.abs(y2 - y0))) < 5e-5
    assert float(jnp.max(jnp.abs(s1T - s0T))) < 5e-5
    assert float(jnp.max(jnp.abs(s2T - s0T))) < 5e-5


def test_ops_dispatch(key):
    from repro.kernels import ops
    B, S, H, D = 1, 128, 2, 64
    ks = jax.random.split(key, 3)
    q, k, v = (rand(ks[i], (B, S, H, D), jnp.float32) for i in range(3))
    a = ops.prefill_attention(q, k, v, impl="pallas")
    b = ops.prefill_attention(q, k, v, impl="xla")
    assert float(jnp.max(jnp.abs(a - b))) < 2e-5
