"""Hypothesis property tests on the system's scheduling invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (ConServeScheduler, ConversationView, TurnView,
                        make_scheduler)
from repro.core.metrics import ConversationRecord, TurnRecord, gmean, summarize
from repro.core.provisioning import (NodeRates, WorkloadStats, min_decoders,
                                     prefiller_saturation_rate, provision)
from repro.core.signals import ClusterView, NodeState, PrefillLatencyCurve
from repro.cluster import paper_deployment
from repro.traces import TraceConfig, generate_trace

SET = settings(max_examples=40, deadline=None,
               suppress_health_check=[HealthCheck.too_slow])


def _view(dec_kv):
    nodes = {0: NodeState(node_id=0, role="prefill")}
    for i, kv in enumerate(dec_kv):
        nodes[i + 1] = NodeState(node_id=i + 1, role="decode",
                                 active_kv_tokens=kv)
    return ClusterView(nodes, PrefillLatencyCurve(1e-9, 4e-5, 0.01))


@SET
@given(st.lists(st.integers(0, 10**6), min_size=1, max_size=16))
def test_conserve_binds_global_min_kv(dec_kv):
    s = ConServeScheduler()
    v = _view(dec_kv)
    pl = s.bind_decoder(ConversationView(0, 0.0, 1000), v)
    assert v.node(pl.node_id).active_kv_tokens == min(dec_kv)
    assert pl.kv_transfer


@SET
@given(st.lists(st.integers(0, 10**6), min_size=1, max_size=8),
       st.integers(1, 64), st.integers(1, 5000))
def test_conserve_never_migrates_tail(dec_kv, n_turns, append):
    s = ConServeScheduler()
    v = _view(dec_kv)
    bound = 1
    for i in range(1, n_turns + 1):
        pl = s.place_turn(TurnView(0, i, append, 10_000 + i * append),
                          bound, v)
        assert pl.node_id == bound and not pl.kv_transfer


@SET
@given(st.floats(1e-7, 1e-5), st.floats(1e-6, 1e-3), st.floats(0.0, 1.0))
def test_prefill_curve_fit_recovers_exact_quadratic(a, b, c):
    curve = PrefillLatencyCurve(a, b, c)
    xs = [128, 512, 2048, 8192, 16384, 32768]
    fit, r2 = PrefillLatencyCurve.fit(xs, [curve.latency_s(x) for x in xs])
    assert r2 > 0.999999
    for x in xs:
        assert abs(fit.latency_s(x) - curve.latency_s(x)) <= \
            1e-6 + 1e-3 * curve.latency_s(x)


@SET
@given(st.floats(5_000.0, 30_000.0), st.floats(200.0, 3_000.0),
       st.floats(10.0, 300.0), st.floats(5_000.0, 40_000.0))
def test_provisioning_inequalities_hold_at_r_star(l_in, l_d, w, peak_kv):
    rates = NodeRates(25_000.0, 1_000.0, 300_000.0)
    stats = WorkloadStats(l_in, l_d, w, peak_kv)
    n = provision(rates, stats)
    r_star = prefiller_saturation_rate(rates, stats)
    n_tp, n_mem = min_decoders(r_star, rates, stats)
    # strictly more than satisfying both (prefiller saturates first)
    assert n > n_tp and n > n_mem


@SET
@given(st.lists(st.floats(0.01, 100.0), min_size=1, max_size=50))
def test_gmean_bounds(xs):
    g = gmean(xs)
    assert min(xs) - 1e-9 <= g <= max(xs) + 1e-9


@SET
@given(st.integers(0, 2**31 - 1), st.integers(5, 25))
def test_simulator_conservation_and_one_transfer(seed, n_convs):
    """For ANY trace: ConServe performs exactly one KV transfer per
    conversation, occupancy drains to zero, and TTFET <= E2E."""
    trace = generate_trace(n_convs, 1.0, TraceConfig(
        seed=seed, first_input_median=2000, first_input_max=8000,
        mean_turns=4.0, max_turns=10, tool_mean_s=0.2))
    sim = paper_deployment("conserve")
    sim.submit(trace).run()
    recs = sim.results()
    assert len(recs) == n_convs  # nothing lost
    for r in recs:
        assert r.n_kv_transfers == 1
        assert r.n_remote_turns == 0
        assert r.ttfet_s <= r.e2e_s + 1e-9
        assert r.ttfet_s > 0
    for node in sim.nodes.values():
        assert node.state.active_kv_tokens == 0
        assert node.state.active_conversations == 0
        assert not node.decode_jobs


@SET
@given(st.integers(0, 2**31 - 1))
def test_turn_records_monotone(seed):
    trace = generate_trace(6, 2.0, TraceConfig(
        seed=seed, first_input_median=1500, first_input_max=4000,
        mean_turns=5.0, max_turns=8))
    sim = paper_deployment("conserve")
    sim.submit(trace).run()
    for r in sim.results():
        ts = [t.first_token_s for t in r.turns]
        assert all(b >= a for a, b in zip(ts, ts[1:]))
        for t in r.turns:
            assert t.last_token_s >= t.first_token_s >= t.arrival_s
