"""Hypothesis property tests on the system's scheduling invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (ConServeScheduler, ConversationView, TurnView,
                        make_scheduler)
from repro.core.metrics import ConversationRecord, TurnRecord, gmean, summarize
from repro.core.provisioning import (NodeRates, WorkloadStats, min_decoders,
                                     prefiller_saturation_rate, provision)
from repro.core.signals import ClusterView, NodeState, PrefillLatencyCurve
from repro.cluster import paper_deployment
from repro.traces import TraceConfig, generate_trace

SET = settings(max_examples=40, deadline=None,
               suppress_health_check=[HealthCheck.too_slow])


def _view(dec_kv):
    nodes = {0: NodeState(node_id=0, role="prefill")}
    for i, kv in enumerate(dec_kv):
        nodes[i + 1] = NodeState(node_id=i + 1, role="decode",
                                 active_kv_tokens=kv)
    return ClusterView(nodes, PrefillLatencyCurve(1e-9, 4e-5, 0.01))


@SET
@given(st.lists(st.integers(0, 10**6), min_size=1, max_size=16))
def test_conserve_binds_global_min_kv(dec_kv):
    s = ConServeScheduler()
    v = _view(dec_kv)
    pl = s.bind_decoder(ConversationView(0, 0.0, 1000), v)
    assert v.node(pl.node_id).active_kv_tokens == min(dec_kv)
    assert pl.kv_transfer


@SET
@given(st.lists(st.integers(0, 10**6), min_size=1, max_size=8),
       st.integers(1, 64), st.integers(1, 5000))
def test_conserve_never_migrates_tail(dec_kv, n_turns, append):
    s = ConServeScheduler()
    v = _view(dec_kv)
    bound = 1
    for i in range(1, n_turns + 1):
        pl = s.place_turn(TurnView(0, i, append, 10_000 + i * append),
                          bound, v)
        assert pl.node_id == bound and not pl.kv_transfer


@SET
@given(st.floats(1e-7, 1e-5), st.floats(1e-6, 1e-3), st.floats(0.0, 1.0))
def test_prefill_curve_fit_recovers_exact_quadratic(a, b, c):
    curve = PrefillLatencyCurve(a, b, c)
    xs = [128, 512, 2048, 8192, 16384, 32768]
    fit, r2 = PrefillLatencyCurve.fit(xs, [curve.latency_s(x) for x in xs])
    assert r2 > 0.999999
    for x in xs:
        assert abs(fit.latency_s(x) - curve.latency_s(x)) <= \
            1e-6 + 1e-3 * curve.latency_s(x)


@SET
@given(st.floats(5_000.0, 30_000.0), st.floats(200.0, 3_000.0),
       st.floats(10.0, 300.0), st.floats(5_000.0, 40_000.0))
def test_provisioning_inequalities_hold_at_r_star(l_in, l_d, w, peak_kv):
    rates = NodeRates(25_000.0, 1_000.0, 300_000.0)
    stats = WorkloadStats(l_in, l_d, w, peak_kv)
    n = provision(rates, stats)
    r_star = prefiller_saturation_rate(rates, stats)
    n_tp, n_mem = min_decoders(r_star, rates, stats)
    # strictly more than satisfying both (prefiller saturates first)
    assert n > n_tp and n > n_mem


@SET
@given(st.lists(st.floats(0.01, 100.0), min_size=1, max_size=50))
def test_gmean_bounds(xs):
    g = gmean(xs)
    assert min(xs) - 1e-9 <= g <= max(xs) + 1e-9


@SET
@given(st.integers(0, 2**31 - 1), st.integers(5, 25))
def test_simulator_conservation_and_one_transfer(seed, n_convs):
    """For ANY trace: ConServe performs exactly one KV transfer per
    conversation, occupancy drains to zero, and TTFET <= E2E."""
    trace = generate_trace(n_convs, 1.0, TraceConfig(
        seed=seed, first_input_median=2000, first_input_max=8000,
        mean_turns=4.0, max_turns=10, tool_mean_s=0.2))
    sim = paper_deployment("conserve")
    sim.submit(trace).run()
    recs = sim.results()
    assert len(recs) == n_convs  # nothing lost
    for r in recs:
        assert r.n_kv_transfers == 1
        assert r.n_remote_turns == 0
        assert r.ttfet_s <= r.e2e_s + 1e-9
        assert r.ttfet_s > 0
    for node in sim.nodes.values():
        assert node.state.active_kv_tokens == 0
        assert node.state.active_conversations == 0
        assert not node.decode_jobs


# --------------------------------------------------------------------------- #
# ragged fused decode chunks vs the per-token reference path (real engine)
# --------------------------------------------------------------------------- #
ENGINE_SET = settings(max_examples=8, deadline=None,
                      suppress_health_check=[HealthCheck.too_slow])


@pytest.fixture(scope="module")
def ragged_pair():
    """Two identical prefilled replicas (fused / reference) plus KV
    snapshots so every hypothesis example starts from the same state —
    decode_steps donates its cache buffers, so each example restores fresh
    copies instead of re-prefilling."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.engine import ReplicaEngine
    from repro.models import build_model

    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def make():
        eng = ReplicaEngine(cfg, params, n_slots=4, max_ctx=128)
        s0, s1 = eng.kv.acquire(), eng.kv.acquire()
        t0, _ = eng.prefill_conversation(s0,
                                         np.arange(11, 48, dtype=np.int32))
        t1, _ = eng.prefill_conversation(s1,
                                         np.arange(100, 111, dtype=np.int32))
        nt = np.zeros(4, np.int32)
        nt[s0], nt[s1] = int(t0), int(t1)
        return eng, nt

    fus, nt = make()
    ref, nt2 = make()
    np.testing.assert_array_equal(nt, nt2)

    def snap(eng):
        return (jax.tree_util.tree_map(jnp.array, eng.kv.caches),
                eng.kv.lengths.copy(), eng.kv.active.copy())

    def restore(eng, s):
        eng.kv.caches = jax.tree_util.tree_map(jnp.array, s[0])
        eng.kv.lengths = s[1].copy()
        eng.kv.active = s[2].copy()

    return fus, ref, (snap(fus), snap(ref)), restore, nt


@ENGINE_SET
@given(st.tuples(st.integers(0, 6), st.integers(0, 6)).filter(
    lambda r: any(r)))
def test_ragged_decode_chunk_token_and_cache_exact(ragged_pair, rems):
    """PROPERTY: for ANY per-slot remaining vector, one ragged fused chunk
    is token-exact and cache-exact against the per-token reference path
    replayed with the same shrinking live mask (a slot with remaining r
    freezes from step r on; remaining 0 means the slot sits out)."""
    import jax
    fus, ref, (snap_f, snap_r), restore, nt0 = ragged_pair
    restore(fus, snap_f)
    restore(ref, snap_r)

    rem = np.zeros(4, np.int32)
    rem[0], rem[1] = rems
    emit = rem > 0
    seq, _ = fus.decode_steps(nt0.copy(), emit, rem)
    assert seq.shape[0] == int(rem.max())

    nt = nt0.copy()
    ref_toks = {s: [] for s in np.flatnonzero(emit)}
    for i in range(int(rem.max())):
        mask = emit & (i < rem)
        sampled, _ = ref.decode_step_all_reference(nt, mask)
        for s in np.flatnonzero(mask):
            ref_toks[s].append(int(sampled[s]))
            nt[s] = int(sampled[s])

    for s in np.flatnonzero(emit):
        assert [int(t) for t in seq[: rem[s], s]] == ref_toks[s]
    np.testing.assert_array_equal(fus.kv.lengths, ref.kv.lengths)
    for a, b in zip(jax.tree_util.tree_leaves(fus.kv.caches),
                    jax.tree_util.tree_leaves(ref.kv.caches)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


# deterministic prompts for slots joining mid-rotation (the rotation
# engine's refill: a freed slot re-prefills between two decode_steps calls)
JOIN_TOKENS = (np.arange(55, 66, dtype=np.int32),
               np.arange(7, 21, dtype=np.int32))


@ENGINE_SET
@given(st.lists(st.tuples(st.integers(1, 6), st.booleans()),
                min_size=1, max_size=4))
def test_split_chunk_cuts_and_joins_match_reference_replay(ragged_pair,
                                                           plan):
    """PROPERTY (the rotation engine's split-chunk contract): decode_steps
    called BACK-TO-BACK on the same donated cache — random chunk-cut
    lengths, ragged per-slot shares, slots JOINING between calls exactly as
    a mid-tail refill does — is token- and cache-exact against the
    per-token reference path replayed with the same schedule."""
    import jax
    fus, ref, (snap_f, snap_r), restore, nt0 = ragged_pair
    restore(fus, snap_f)
    restore(ref, snap_r)

    active = [0, 1]
    nt_f, nt_r = nt0.copy(), nt0.copy()
    joins = 0
    for n, do_join in plan:
        if do_join and joins < len(JOIN_TOKENS):
            # a refill joins between two chunk cuts: fresh slot, fresh
            # prefill, identical on both engines
            toks = JOIN_TOKENS[joins]
            sf, sr = fus.kv.acquire(), ref.kv.acquire()
            assert sf == sr
            tf, _ = fus.prefill_conversation(sf, toks)
            tr, _ = ref.prefill_conversation(sr, toks)
            assert int(tf) == int(tr)
            nt_f[sf] = nt_r[sr] = int(tf)
            active.append(sf)
            joins += 1
        emit = np.zeros(4, bool)
        emit[active] = True
        rem = np.zeros(4, np.int32)
        for s in active:  # ragged per-slot shares, derived from the draw
            rem[s] = 1 + (n + s) % 6
        seq, _ = fus.decode_steps(nt_f, emit, rem)
        # reference: per-token replay with the same shrinking live mask
        ref_toks = {s: [] for s in active}
        for i in range(int(rem.max())):
            mask = emit & (i < rem)
            sampled, _ = ref.decode_step_all_reference(nt_r, mask)
            for s in np.flatnonzero(mask):
                ref_toks[s].append(int(sampled[s]))
                nt_r[s] = int(sampled[s])
        for s in active:
            assert [int(t) for t in seq[: rem[s], s]] == ref_toks[s]
            nt_f[s] = int(seq[rem[s] - 1, s])
    np.testing.assert_array_equal(fus.kv.lengths, ref.kv.lengths)
    for a, b in zip(jax.tree_util.tree_leaves(fus.kv.caches),
                    jax.tree_util.tree_leaves(ref.kv.caches)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


# --------------------------------------------------------------------------- #
# jitted (append-)prefill vs the eager reference path (real engine)
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def prefill_pair():
    """One jitted and one eager-reference replica sharing params; each
    hypothesis example drives a fresh slot through (turn-1 length, append
    length) and releases it, so examples are independent."""
    import jax
    from repro.configs import get_reduced
    from repro.engine import ReplicaEngine
    from repro.models import build_model

    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    jit_eng = ReplicaEngine(cfg, params, n_slots=2, max_ctx=256,
                            prefill_mode="jit")
    ref_eng = ReplicaEngine(cfg, params, n_slots=2, max_ctx=256,
                            prefill_mode="reference")
    return jit_eng, ref_eng


@ENGINE_SET
@given(st.integers(1, 150), st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_jit_append_prefill_token_and_cache_exact(prefill_pair, prefix_len,
                                                  append_len, seed):
    """PROPERTY: for ANY (prefix length, append length) pair, the jitted
    append-prefill — donated in-slot scatter, dynamic-slice prefix read
    trimmed to the ctx bucket — is token-exact against the eager reference
    path, and the slot's cache rows are byte-identical afterwards."""
    import jax
    jit_eng, ref_eng = prefill_pair
    rng = np.random.RandomState(seed)
    t1 = rng.randint(0, jit_eng.cfg.vocab_size,
                     size=prefix_len).astype(np.int32)
    app = rng.randint(0, jit_eng.cfg.vocab_size,
                      size=append_len).astype(np.int32)
    toks = {}
    rows = {}
    for name, eng in (("jit", jit_eng), ("ref", ref_eng)):
        s = eng.kv.acquire()
        a, _ = eng.prefill_conversation(s, t1)
        b, _ = eng.append_prefill(s, app)
        toks[name] = (int(a), int(b))
        rows[name] = [np.asarray(l, np.float32) for l in
                      jax.tree_util.tree_leaves(
                          eng.kv.export_slot(s)["caches"])]
        eng.kv.release(s)
    assert toks["jit"] == toks["ref"]
    for a, b in zip(rows["jit"], rows["ref"]):
        np.testing.assert_array_equal(a, b)


@SET
@given(st.integers(0, 2**31 - 1))
def test_turn_records_monotone(seed):
    trace = generate_trace(6, 2.0, TraceConfig(
        seed=seed, first_input_median=1500, first_input_max=4000,
        mean_turns=5.0, max_turns=8))
    sim = paper_deployment("conserve")
    sim.submit(trace).run()
    for r in sim.results():
        ts = [t.first_token_s for t in r.turns]
        assert all(b >= a for a, b in zip(ts, ts[1:]))
        for t in r.turns:
            assert t.last_token_s >= t.first_token_s >= t.arrival_s
