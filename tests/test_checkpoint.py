"""Checkpoint atomicity, roundtrip, and elastic restore."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.models import build_model
from repro.train import (adamw_init, latest_step, restore_checkpoint,
                         save_checkpoint)


@pytest.fixture()
def setup(key, tmp_path):
    cfg = get_reduced("olmo-1b")
    model = build_model(cfg)
    params = model.init(key)
    opt = adamw_init(params)
    return model, params, opt, tmp_path


def test_roundtrip(setup):
    model, params, opt, d = setup
    save_checkpoint(d, 7, params, opt, extra={"tokens_seen": 123})
    assert latest_step(d) == 7
    p2, o2, extra = restore_checkpoint(d, 7, params, opt)
    assert extra["tokens_seen"] == 123
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        assert bool(jnp.array_equal(a, b))
    assert int(o2["step"]) == int(opt["step"])


def test_latest_step_picks_newest_complete(setup):
    model, params, opt, d = setup
    save_checkpoint(d, 1, params, opt)
    save_checkpoint(d, 5, params, opt)
    # simulate a crashed write: dir without manifest
    (Path(d) / "step_9").mkdir()
    assert latest_step(d) == 5


def test_restore_into_skeleton_structs(setup):
    """Restore targets may be ShapeDtypeStructs (fresh process, no init)."""
    model, params, opt, d = setup
    save_checkpoint(d, 3, params, opt)
    sk = model.skeleton()
    from repro.train.optimizer import adamw_state_skeleton
    p2, o2, _ = restore_checkpoint(d, 3, sk, adamw_state_skeleton(sk))
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        assert bool(jnp.array_equal(a, b))


def test_shape_mismatch_raises(setup):
    model, params, opt, d = setup
    save_checkpoint(d, 2, params, opt)
    bad = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct((l.shape[0] + 1, *l.shape[1:]),
                                       l.dtype) if l.ndim else l, params)
    with pytest.raises(ValueError):
        restore_checkpoint(d, 2, bad, opt)
