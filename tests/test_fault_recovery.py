"""Failure contract on the REAL engine (EngineServer) plus cross-backend
parity: journaled deterministic replay after replica death, lazy recovery
from TOOL_WAIT, tool-deadline watchdogs, injectable KV-transfer faults with
bounded retry, and loud no-healthy-target errors.

The correctness bar is byte-identity: every recovered per-(cid, turn) token
stream must equal the failure-free run's exactly — replica determinism plus
the journal make recovery observation-only (no predicted/approximate state
is ever reconstructed). Engine event times are real wall measurements, so
failures are injected at STRUCTURAL points (a chosen conversation entering
DECODING / TOOL_WAIT) rather than absolute times wherever a test needs a
guaranteed victim; the hypothesis schedule property covers arbitrary
(victim, time) combinations on top.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import make_scheduler
from repro.core.conversation import Conversation, Turn
from repro.core.metrics import summarize
from repro.core.runtime import DECODING, TOOL_WAIT
from repro.core.signals import NODE_ACTIVE
from repro.engine import EngineServer, ReplicaEngine
from repro.models import build_model

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # property tests fall back to a seeded schedule sweep
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="module")
def qwen():
    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# multi-turn conversations with real tool waits: failures can land mid-tail,
# during TOOL_WAIT, and between turns
def _trace(n=4):
    return [Conversation(cid=i, arrival_s=i * 1e-6, turns=[
        Turn(append_tokens=24 + 4 * i, output_tokens=10, tool_time_s=0.05),
        Turn(append_tokens=10 + 2 * i, output_tokens=8, tool_time_s=0.0),
    ]) for i in range(n)]


def _disagg(cfg, params, **kw):
    reps = [ReplicaEngine(cfg, params, n_slots=6, max_ctx=256,
                          replica_id=0, role="prefill"),
            ReplicaEngine(cfg, params, n_slots=3, max_ctx=256,
                          replica_id=1, role="decode"),
            ReplicaEngine(cfg, params, n_slots=3, max_ctx=256,
                          replica_id=2, role="decode")]
    return EngineServer(make_scheduler("conserve"), reps,
                        record_tokens=True, strict_accounting=True, **kw)


@pytest.fixture(scope="module")
def baseline(qwen):
    """Failure-free disaggregated run: the byte-identity reference."""
    cfg, _, params = qwen
    srv = _disagg(cfg, params)
    recs = srv.serve(_trace())
    assert len(recs) == 4 and not any(r.recovered for r in recs)
    span = max(t.last_token_s for r in recs for t in r.turns)
    return srv.sampled_tokens, span


class _FailWhen(EngineServer):
    """Kill the replica hosting `victim_cid` the moment that conversation
    enters the chosen stage of `victim_turn` — a structural trigger that
    does not depend on wall-clock event times."""

    def __init__(self, *a, victim_cid=0, victim_turn=0, stage=DECODING,
                 **kw):
        super().__init__(*a, **kw)
        self._victim = (victim_cid, victim_turn)
        self._stage = stage
        self._armed = True

    def _maybe_fail(self, cid):
        sess = self.sessions[cid]
        if (self._armed and cid == self._victim[0]
                and sess.state == self._stage and cid in self._slots):
            self._armed = False
            # fires BEFORE any completion event of the in-flight work (those
            # land at measured wall offsets, far beyond 1ns)
            self.fail_replica(self._slots[cid][0], self._now + 1e-9)

    def _begin_decode(self, conv, turn_idx, next_tok, ready_t,
                      arrival_t=None):
        super()._begin_decode(conv, turn_idx, next_tok, ready_t,
                              arrival_t=arrival_t)
        if self._stage == DECODING and turn_idx == self._victim[1]:
            self._maybe_fail(conv.cid)

    def _finish_turn(self, task, t):
        super()._finish_turn(task, t)
        if self._stage == TOOL_WAIT and task.turn_idx + 1 == self._victim[1]:
            self._maybe_fail(task.conv.cid)


# --------------------------------------------------------------------------- #
# decoder death with a guaranteed mid-turn victim
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("victim_turn", [0, 1])
def test_decoder_death_mid_turn_replays_byte_identical(qwen, baseline,
                                                       victim_turn):
    cfg, _, params = qwen
    tokens, _ = baseline
    srv = _FailWhen(*_args(cfg, params), victim_cid=1,
                    victim_turn=victim_turn, stage=DECODING,
                    record_tokens=True, strict_accounting=True)
    recs = srv.serve(_trace())
    assert len(recs) == 4
    assert srv.n_recoveries >= 1
    assert srv.records[1].recovered
    # the correctness bar: every stream byte-identical to the failure-free run
    assert srv.sampled_tokens == tokens
    # recovery latency closed (trigger -> interrupted decode runnable)
    assert srv.records[1].recovery_latency_s
    assert all(l > 0 for r in recs for l in r.recovery_latency_s)
    # replay charged to the dedicated observable, never the victim's turns
    assert sum(s.replayed_prefill_tokens
               for s in srv.states.values() if s.alive) > 0
    dead = next(s for s in srv.states.values() if not s.alive)
    assert dead.active_kv_tokens == 0 and dead.used_slots == 0
    srv.check_accounting()


def _args(cfg, params):
    reps = [ReplicaEngine(cfg, params, n_slots=6, max_ctx=256,
                          replica_id=0, role="prefill"),
            ReplicaEngine(cfg, params, n_slots=3, max_ctx=256,
                          replica_id=1, role="decode"),
            ReplicaEngine(cfg, params, n_slots=3, max_ctx=256,
                          replica_id=2, role="decode")]
    return make_scheduler("conserve"), reps


def test_death_during_tool_wait_recovers_lazily(qwen, baseline):
    """The replica dies while the victim is TOOL_WAITing on it: nothing to
    replay until the tool returns — then the dead binding is observed and
    the conversation re-admits by journaled replay."""
    cfg, _, params = qwen
    tokens, _ = baseline
    srv = _FailWhen(*_args(cfg, params), victim_cid=2, victim_turn=1,
                    stage=TOOL_WAIT, record_tokens=True,
                    strict_accounting=True)
    recs = srv.serve(_trace())
    assert len(recs) == 4
    assert srv.records[2].recovered
    assert srv.sampled_tokens == tokens
    assert srv.records[2].recovery_latency_s
    srv.check_accounting()


def test_failure_free_run_records_no_recovery(baseline, qwen):
    cfg, _, params = qwen
    srv = _disagg(cfg, params)
    recs = srv.serve(_trace())
    s = summarize(recs)
    assert s["n_recovered"] == 0 and s["n_tool_evictions"] == 0
    assert s["recovery_latency_mean_s"] == 0.0
    assert all(st.replayed_prefill_tokens == 0 for st in srv.states.values())


def test_recovery_summary_keys(qwen, baseline):
    cfg, _, params = qwen
    srv = _FailWhen(*_args(cfg, params), victim_cid=0, victim_turn=0,
                    stage=DECODING, record_tokens=True)
    recs = srv.serve(_trace())
    s = summarize(recs)
    assert s["n_recovered"] >= 1
    assert s["recovery_latency_mean_s"] > 0
    assert s["recovery_latency_p95_s"] >= s["recovery_latency_mean_s"] * 0.5


# --------------------------------------------------------------------------- #
# random seeded failure schedules: byte-identity is schedule-independent
# --------------------------------------------------------------------------- #
def _check_schedule(qwen, baseline, victim, frac):
    """For ANY (victim decoder, failure time) drawn over the serving span,
    every conversation completes and every per-(cid, turn) stream equals
    the failure-free run's byte for byte."""
    cfg, _, params = qwen
    tokens, span = baseline
    srv = _disagg(cfg, params)
    srv.fail_replica(victim, frac * span)
    recs = srv.serve(_trace())
    assert len(recs) == 4
    assert all(s.done for s in srv.sessions.values())
    assert srv.sampled_tokens == tokens
    srv.check_accounting()


# always-on seeded sweep (no hypothesis dependency): fixed pseudo-random
# (victim, time-fraction) schedules drawn once from a seeded RNG
_RNG = np.random.RandomState(20260807)
_SCHEDULES = [(int(_RNG.randint(1, 3)), float(_RNG.uniform(0.02, 0.98)))
              for _ in range(4)]


@pytest.mark.parametrize("victim,frac", _SCHEDULES,
                         ids=[f"n{v}@{f:.2f}" for v, f in _SCHEDULES])
def test_seeded_failure_schedule_is_byte_identical(qwen, baseline, victim,
                                                   frac):
    _check_schedule(qwen, baseline, victim, frac)


if HAVE_HYPOTHESIS:
    # real-engine property runs are slow: few examples, no deadline
    ENGINE_SET = settings(max_examples=6, deadline=None,
                          suppress_health_check=[HealthCheck.too_slow])

    @ENGINE_SET
    @given(victim=st.sampled_from([1, 2]), frac=st.floats(0.02, 0.98))
    def test_any_failure_schedule_is_byte_identical(qwen, baseline, victim,
                                                    frac):
        _check_schedule(qwen, baseline, victim, frac)


# --------------------------------------------------------------------------- #
# lifecycle schedules: kill -> rejoin (+ an optional quarantine-armed
# slowdown) keeps byte-identity; the rejoined replica ends ACTIVE
# --------------------------------------------------------------------------- #
def _check_lifecycle_schedule(qwen, baseline, victim, frac, rejoin_delta,
                              slow=False):
    """For ANY (victim decoder, kill time, rejoin delay) — optionally with a
    sustained slowdown on the OTHER decoder ordered strictly after the
    rejoin, so an ACTIVE decoder exists at every instant — every
    conversation completes, every stream equals the failure-free run's byte
    for byte, and the rejoined victim is back in the ACTIVE set at the end.
    Whether the slowdown actually trips the quarantine depends on how much
    observable work the straggler holds (the soak benchmark pins that
    down); byte-identity and completion must hold either way."""
    cfg, _, params = qwen
    tokens, span = baseline
    srv = _disagg(cfg, params, quarantine_k=3.0, quarantine_window=2)
    t_kill = frac * span
    t_rejoin = t_kill + rejoin_delta * span
    srv.fail_replica(victim, t_kill).recover_replica(victim, t_rejoin)
    if slow:
        other = 3 - victim  # the one decode peer in the disagg pair
        srv.inject_slowdown(other, 8.0, at_s=t_rejoin + 0.05 * span)
        srv.inject_slowdown(other, 1.0, at_s=t_rejoin + 0.35 * span)
    recs = srv.serve(_trace())
    assert len(recs) == 4
    assert all(s.done for s in srv.sessions.values())
    assert srv.sampled_tokens == tokens
    st = srv.states[victim]
    assert st.alive and st.lifecycle == NODE_ACTIVE
    srv.check_accounting()


_LC_RNG = np.random.RandomState(20260808)
_LC_SCHEDULES = [(int(_LC_RNG.randint(1, 3)),
                  float(_LC_RNG.uniform(0.05, 0.5)),
                  float(_LC_RNG.uniform(0.05, 0.2)),
                  bool(_LC_RNG.randint(0, 2)))
                 for _ in range(4)]


@pytest.mark.parametrize(
    "victim,frac,rejoin_delta,slow", _LC_SCHEDULES,
    ids=[f"n{v}@{f:.2f}+{d:.2f}{'slow' if s else ''}"
         for v, f, d, s in _LC_SCHEDULES])
def test_seeded_lifecycle_schedule_is_byte_identical(qwen, baseline, victim,
                                                     frac, rejoin_delta,
                                                     slow):
    _check_lifecycle_schedule(qwen, baseline, victim, frac, rejoin_delta,
                              slow)


if HAVE_HYPOTHESIS:
    @ENGINE_SET
    @given(victim=st.sampled_from([1, 2]), frac=st.floats(0.05, 0.5),
           rejoin_delta=st.floats(0.05, 0.2), slow=st.booleans())
    def test_any_lifecycle_schedule_is_byte_identical(
            qwen, baseline, victim, frac, rejoin_delta, slow):
        _check_lifecycle_schedule(qwen, baseline, victim, frac,
                                  rejoin_delta, slow)


def test_mixed_node_death_with_parked_arrivals(qwen):
    """Overloaded mixed pair: node 0 dies holding parked arrival admissions;
    they re-place through place_first_prefill onto the survivor, and the
    whole overloaded trace still completes byte-identically."""
    cfg, _, params = qwen

    def mixed_pair():
        return [ReplicaEngine(cfg, params, n_slots=2, max_ctx=256,
                              replica_id=i, role="mixed") for i in (0, 1)]

    trace = _trace(6)  # 6 concurrent conversations vs 4 slots: some park
    base = EngineServer(make_scheduler("conserve"), mixed_pair(),
                        record_tokens=True, strict_accounting=True)
    base_recs = base.serve(trace)
    assert len(base_recs) == 6

    srv = _FailWhen(make_scheduler("conserve"), mixed_pair(),
                    victim_cid=0, victim_turn=0, stage=DECODING,
                    record_tokens=True, strict_accounting=True)
    recs = srv.serve(trace)
    assert len(recs) == 6
    assert srv.sampled_tokens == base.sampled_tokens
    assert srv.n_recoveries >= 1
    srv.check_accounting()


# --------------------------------------------------------------------------- #
# prefix pool corpse contract: pooled rows die with the node's slot cache
# --------------------------------------------------------------------------- #
_PREAMBLE = 24


def _pooled_pair(cfg, params):
    return [ReplicaEngine(cfg, params, n_slots=3, max_ctx=256, replica_id=i,
                          role="mixed", prefix_pool_tokens=4 * _PREAMBLE)
            for i in (0, 1)]


def _preamble_trace(n=5):
    """Shared-preamble fleet, arrivals spaced so each prefill (tens of ms)
    lands before the next arrival probes the pool."""
    return [Conversation(cid=i, arrival_s=0.3 * i, turns=[
        Turn(append_tokens=_PREAMBLE + 12 + 2 * i, output_tokens=6,
             tool_time_s=0.05),
        Turn(append_tokens=8, output_tokens=5, tool_time_s=0.0)],
        preamble_id=0, preamble_tokens=_PREAMBLE) for i in range(n)]


@pytest.fixture(scope="module")
def pooled_baseline(qwen):
    cfg, _, params = qwen
    srv = EngineServer(make_scheduler("conserve"), _pooled_pair(cfg, params),
                       record_tokens=True, strict_accounting=True)
    recs = srv.serve(_preamble_trace())
    assert len(recs) == 5
    assert sum(s.pooled_prefix_hits for s in srv.states.values()) > 0
    span = max(t.last_token_s for r in recs for t in r.turns)
    return srv.sampled_tokens, span


# fixed pseudo-random (victim, time-fraction) schedules: pooled rows must
# die with the node and recovery must re-populate through the normal miss
# path — never a dangling reference to dead device buffers
_POOL_RNG = np.random.RandomState(7_2026)
_POOL_SCHEDULES = [(int(_POOL_RNG.randint(0, 2)),
                    float(_POOL_RNG.uniform(0.05, 0.95)))
                   for _ in range(3)]


@pytest.mark.parametrize("victim,frac", _POOL_SCHEDULES,
                         ids=[f"n{v}@{f:.2f}" for v, f in _POOL_SCHEDULES])
def test_seeded_failure_invalidates_pool_and_replays_identical(
        qwen, pooled_baseline, victim, frac):
    """A replica death takes its pooled prefix rows with it (same
    invalidate_all moment as the slot cache); recovered conversations
    re-populate the survivor's pool, and every stream stays byte-identical
    to the pooled failure-free run."""
    cfg, _, params = qwen
    tokens, span = pooled_baseline
    srv = EngineServer(make_scheduler("conserve"), _pooled_pair(cfg, params),
                       record_tokens=True, strict_accounting=True)
    srv.fail_replica(victim, frac * span)
    recs = srv.serve(_preamble_trace())
    assert len(recs) == 5
    assert all(s.done for s in srv.sessions.values())
    assert srv.sampled_tokens == tokens

    dead = srv.states[victim]
    assert not dead.alive
    # resident pool observables zero on the corpse, ground truth agrees
    assert dead.pooled_prefix_tokens == 0 and dead.pooled_prefix_entries == 0
    assert srv.replicas[victim].prefix_pool.n_entries == 0
    # the shared-preamble fleet keeps (or re-establishes) pooled rows on the
    # survivor — recovery goes through the normal populate-on-miss path
    survivor = srv.states[1 - victim]
    assert survivor.pooled_prefix_entries >= 1
    srv.check_accounting()  # includes the pool mirror reconciliation


def test_pool_survives_failure_free_pooled_run(qwen, pooled_baseline):
    """Control for the corpse contract: without a failure the pooled rows
    stay resident to the end of the serve."""
    cfg, _, params = qwen
    srv = EngineServer(make_scheduler("conserve"), _pooled_pair(cfg, params),
                       record_tokens=True, strict_accounting=True)
    srv.serve(_preamble_trace())
    assert any(s.pooled_prefix_entries > 0 for s in srv.states.values())
    assert all(s.alive for s in srv.states.values())


# --------------------------------------------------------------------------- #
# loud failure modes
# --------------------------------------------------------------------------- #
def test_no_healthy_decoder_raises(qwen):
    cfg, _, params = qwen
    reps = [ReplicaEngine(cfg, params, n_slots=4, max_ctx=256,
                          replica_id=0, role="prefill"),
            ReplicaEngine(cfg, params, n_slots=2, max_ctx=256,
                          replica_id=1, role="decode")]
    srv = EngineServer(make_scheduler("conserve"), reps)
    srv.fail_replica(1, 0.0)  # the only decoder dies before any arrival
    with pytest.raises(RuntimeError, match="no healthy decoder"):
        srv.serve(_trace(2))


def test_double_failure_of_same_replica_raises(qwen):
    cfg, _, params = qwen
    srv = _disagg(cfg, params)
    srv.fail_replica(1, 0.0).fail_replica(1, 1e-6)
    with pytest.raises(RuntimeError, match="failed twice"):
        srv.serve(_trace(2))


# --------------------------------------------------------------------------- #
# tool-deadline watchdog
# --------------------------------------------------------------------------- #
def test_tool_watchdog_evicts_and_replays_byte_identical(qwen):
    """One slot, two conversations: A's slow tool holds the slot until the
    watchdog evicts it, B admits into the freed slot, A's tool return
    re-admits by replay — both complete with unchanged streams."""
    cfg, _, params = qwen
    trace = [Conversation(cid=0, arrival_s=0.0, turns=[
                 Turn(append_tokens=24, output_tokens=8, tool_time_s=5.0),
                 Turn(append_tokens=10, output_tokens=6, tool_time_s=0.0)]),
             Conversation(cid=1, arrival_s=1e-6, turns=[
                 Turn(append_tokens=20, output_tokens=8, tool_time_s=0.0)])]

    def one_slot(**kw):
        rep = ReplicaEngine(cfg, params, n_slots=1, max_ctx=256,
                            replica_id=0, role="mixed")
        return EngineServer(make_scheduler("conserve"), [rep],
                            record_tokens=True, strict_accounting=True, **kw)

    base = one_slot()
    base_recs = base.serve(trace)
    assert len(base_recs) == 2

    srv = one_slot(tool_deadline_s=0.5, tool_timeout_action="evict")
    recs = srv.serve(trace)
    assert len(recs) == 2
    assert srv.n_tool_evictions == 1
    assert srv.records[0].n_tool_evictions == 1
    assert srv.records[0].recovered  # re-admitted by replay
    assert srv.sampled_tokens == base.sampled_tokens
    # B stopped waiting the moment the slot freed, long before A's tool came
    # back at t=5: its queue wait is bounded by the deadline, not the tool
    assert srv.sessions[1].queue_wait_s < 5.0
    s = summarize(recs)
    assert s["n_tool_evictions"] == 1 and s["n_recovered"] == 1
    srv.check_accounting()


def test_tool_watchdog_noop_when_tool_returns_in_time(qwen):
    cfg, _, params = qwen
    srv = _disagg(cfg, params, tool_deadline_s=30.0)  # far beyond any tool
    recs = srv.serve(_trace())
    assert len(recs) == 4
    assert srv.n_tool_evictions == 0
    assert not any(r.recovered for r in recs)


def test_tool_watchdog_fail_action_raises(qwen):
    cfg, _, params = qwen
    trace = [Conversation(cid=0, arrival_s=0.0, turns=[
        Turn(append_tokens=24, output_tokens=8, tool_time_s=5.0),
        Turn(append_tokens=10, output_tokens=6, tool_time_s=0.0)])]
    srv = _disagg(cfg, params, tool_deadline_s=0.5,
                  tool_timeout_action="fail")
    with pytest.raises(RuntimeError, match="exceeded the tool deadline"):
        srv.serve(trace)


# --------------------------------------------------------------------------- #
# injectable KV-transfer faults with bounded retry
# --------------------------------------------------------------------------- #
def test_transfer_fault_retries_to_success(qwen, baseline):
    cfg, _, params = qwen
    tokens, _ = baseline
    srv = _disagg(cfg, params)
    srv.inject_transfer_faults(1)
    recs = srv.serve(_trace())
    assert len(recs) == 4
    assert srv.n_transfer_retries == 1
    assert srv.sampled_tokens == tokens  # faults never change content
    assert any("KV transfer" in line and "FAILED" in line
               for line in srv.log)
    srv.check_accounting()


def test_transfer_fault_budget_exhaustion_raises(qwen):
    cfg, _, params = qwen
    srv = _disagg(cfg, params, max_transfer_retries=2)
    srv.inject_transfer_faults(10)  # every attempt of one binding faults
    with pytest.raises(RuntimeError, match="consecutive attempts"):
        srv.serve(_trace(2))
