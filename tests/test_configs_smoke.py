"""Per-architecture smoke tests: a REDUCED config of the same family (same
layer kinds / code paths, tiny dims) runs one forward + one train step on
CPU; output shapes and finiteness are asserted. The FULL published configs
are exercised via the dry-run only (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, ASSIGNED, get_config, get_reduced
from repro.models import build_model
from repro.train import AdamWConfig, adamw_init, make_train_step

B, S = 2, 24


def _inputs(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    fe = None
    if cfg.frontend != "none":
        fl = cfg.frontend_len or cfg.encoder_seq
        fe = jax.random.normal(jax.random.fold_in(key, 7),
                               (B, fl, cfg.d_model), cfg.jnp_dtype) * 0.02
    return toks, fe


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_smoke(arch, key):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(key)
    toks, fe = _inputs(cfg, key)
    h = model.hidden(params, toks, frontend_embeds=fe)
    assert h.shape == (B, S, cfg.d_model)
    assert not bool(jnp.isnan(h.astype(jnp.float32)).any())
    logits, caches = model.prefill(params, toks, frontend_embeds=fe)
    assert logits.shape == (B, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    F = cfg.frontend_len if (cfg.frontend == "vision") else 0
    lg, ups = model.decode_step(params, toks[:, -1], caches,
                                jnp.full((B,), F + S, jnp.int32))
    assert lg.shape == (B, cfg.padded_vocab)
    assert not bool(jnp.isnan(lg.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch, key):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(key)
    opt = adamw_init(params)
    step = make_train_step(model, AdamWConfig(warmup_steps=1, total_steps=10),
                           loss_chunk=16)
    toks, fe = _inputs(cfg, key)
    batch = {"tokens": toks, "labels": toks}
    if fe is not None:
        batch["frontend_embeds"] = fe
    params2, opt2, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    assert int(opt2["step"]) == 1
    # params actually changed
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, params2)
    assert max(jax.tree_util.tree_leaves(diffs)) > 0


def test_full_configs_match_assignment():
    """The published config numbers are encoded exactly."""
    c = get_config("gemma3-12b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (48, 3840, 16, 8, 15360, 262144)
    assert c.block_pattern.count("attn_local") == 5  # 5:1 local:global
    c = get_config("stablelm-12b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 5120, 32, 8, 13824, 100352)
    c = get_config("nemotron-4-15b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff) == (32, 6144, 48, 24576)
    assert c.activation == "squared_relu" and not c.gated_mlp
    c = get_config("olmo-1b")
    assert (c.n_layers, c.d_model, c.vocab_size) == (16, 2048, 50304)
    assert c.norm == "nonparametric_ln"
    c = get_config("internvl2-26b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab_size) == (
        48, 6144, 48, 92553)
    assert c.frontend == "vision"
    c = get_config("deepseek-v2-lite-16b")
    assert (c.n_layers, c.d_model, c.n_experts, c.top_k,
            c.kv_lora_rank) == (27, 2048, 64, 6, 512)
    c = get_config("llama4-scout-17b-a16e")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_experts, c.top_k) == (
        48, 5120, 40, 16, 1)
    c = get_config("rwkv6-3b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == (
        32, 2560, 8960, 65536)
    assert c.attention_free
    c = get_config("whisper-small")
    assert c.is_encoder_decoder and (c.n_layers, c.d_model) == (12, 768)
    c = get_config("recurrentgemma-9b")
    assert (c.n_layers, c.d_model, c.n_kv_heads, c.d_ff) == (38, 4096, 1, 12288)
    assert c.block_pattern == ("rglru", "rglru", "attn_local")


def test_param_counts_in_published_range():
    """Analytic parameter counts land near the advertised model sizes."""
    expect = {
        "gemma3-12b": (10e9, 14e9),
        "stablelm-12b": (10e9, 14e9),
        "nemotron-4-15b": (13e9, 18e9),
        "olmo-1b": (0.9e9, 1.6e9),
        "deepseek-v2-lite-16b": (13e9, 18e9),
        "llama4-scout-17b-a16e": (90e9, 120e9),  # total (incl. all experts)
        "rwkv6-3b": (2.5e9, 4e9),
        "recurrentgemma-9b": (7.5e9, 11e9),
        "whisper-small": (0.2e9, 0.35e9),
        "internvl2-26b": (18e9, 26e9),  # LLM backbone (ViT stubbed)
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"
