"""Instance-configuration math (§4.1) incl. the paper's own sanity check."""
import math

from repro.core.provisioning import (NodeRates, WorkloadStats, min_decoders,
                                     paper_configuration,
                                     prefiller_saturation_rate, provision,
                                     slots_per_decoder)


def test_paper_sanity_check():
    """§5.1: 25k tok/s prefill, 15k in + 1k out per conversation =>
    R* = 1.67 conv/s and >= 1.67 decoders per prefiller; N=3 more than
    satisfies the bound (prefiller saturates first)."""
    rates, stats = paper_configuration()
    r_star = prefiller_saturation_rate(rates, stats)
    assert abs(r_star - 25_000 / 15_000) < 1e-9
    n_tp, n_mem = min_decoders(r_star, rates, stats)
    assert abs(n_tp - 1.6667) < 1e-3
    n = provision(rates, stats)
    assert n > max(n_tp, n_mem)
    assert n <= 3  # the paper's 3-decoder box satisfies it with slack


def test_slots_from_memory():
    rates, stats = paper_configuration()
    b = slots_per_decoder(rates, stats)
    assert b == int(300_000 // 16_000)


def test_memory_constraint_can_dominate():
    rates = NodeRates(25_000, 1_000, 50_000)
    stats = WorkloadStats(mean_first_input=15_000, mean_decoder_volume=100,
                          mean_lifetime_s=600, mean_peak_kv_tokens=25_000)
    n_tp, n_mem = min_decoders(1.0, rates, stats)
    assert n_mem > n_tp  # slots bind before throughput
    assert provision(rates, stats) > n_mem / 1.0 * 0  # positive integer
