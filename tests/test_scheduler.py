"""Unit tests for scheduler policies over observable state only."""
import pytest

from repro.core import (AMPDScheduler, ConServeScheduler, ConversationView,
                        FullDisaggScheduler, TurnView, make_scheduler)
from repro.core.signals import ClusterView, NodeState, PrefillLatencyCurve


def make_view(pf_queues=(0,), dec_kv=(0, 0, 0), tbt=None):
    nodes = {}
    nid = 0
    for q in pf_queues:
        nodes[nid] = NodeState(node_id=nid, role="prefill",
                               queued_prefill_tokens=q)
        nid += 1
    for i, kv in enumerate(dec_kv):
        n = NodeState(node_id=nid, role="decode", active_kv_tokens=kv)
        if tbt:
            n.observed_tbt_ema_s = tbt[i]
        nodes[nid] = n
        nid += 1
    return ClusterView(nodes, PrefillLatencyCurve(1e-9, 4e-5, 0.01))


CONV = ConversationView(cid=1, arrival_s=0.0, first_input_len=15000)


class TestConServe:
    def test_first_prefill_routes_to_prefiller(self):
        s = ConServeScheduler()
        pl = s.place_first_prefill(CONV, make_view())
        assert pl.node_id == 0 and not pl.kv_transfer

    def test_least_backlogged_prefiller(self):
        s = ConServeScheduler()
        v = make_view(pf_queues=(50_000, 1_000))
        assert s.place_first_prefill(CONV, v).node_id == 1

    def test_bind_min_kv_decoder_with_single_transfer(self):
        s = ConServeScheduler()
        v = make_view(dec_kv=(90_000, 20_000, 50_000))
        pl = s.bind_decoder(CONV, v)
        assert v.node(pl.node_id).active_kv_tokens == 20_000
        assert pl.kv_transfer  # the one and only

    def test_turns_pinned_no_transfer(self):
        s = ConServeScheduler()
        v = make_view()
        s.bind_decoder(CONV, v)
        for idx in range(1, 30):
            t = TurnView(cid=1, turn_idx=idx, append_tokens=300,
                         context_tokens=16000 + 300 * idx)
            pl = s.place_turn(t, bound_decoder=2, view=v)
            assert pl.node_id == 2 and not pl.kv_transfer

    def test_straggler_screening_is_observational(self):
        s = ConServeScheduler(straggler_factor=3.0)
        v = make_view(dec_kv=(10, 20, 30), tbt=(0.5, 0.02, 0.02))
        # node with min KV (10) is a 25x straggler -> excluded from binding
        pl = s.bind_decoder(CONV, v)
        assert v.node(pl.node_id).observed_tbt_ema_s <= 0.06


class TestBaselines:
    def test_full_disagg_migrates_every_turn(self):
        s = FullDisaggScheduler()
        v = make_view()
        t = TurnView(cid=1, turn_idx=3, append_tokens=200, context_tokens=16000)
        pl = s.place_turn(t, bound_decoder=2, view=v)
        assert v.node(pl.node_id).role == "prefill" and pl.kv_transfer

    def test_ampd_zero_error_reduces_to_conserve(self):
        s = AMPDScheduler(wrong_prediction_rate=0.0)
        v = make_view()
        for idx in range(1, 50):
            t = TurnView(cid=1, turn_idx=idx, append_tokens=250,
                         context_tokens=15000)
            pl = s.place_turn(t, bound_decoder=3, view=v)
            assert pl.node_id == 3 and not pl.kv_transfer

    def test_ampd_error_rate_controls_migrations(self):
        s = AMPDScheduler(wrong_prediction_rate=0.25, seed=42)
        v = make_view()
        n = 4000
        remote = sum(
            s.place_turn(TurnView(1, i, 250, 15000), 3, v).kv_transfer
            for i in range(n))
        assert abs(remote / n - 0.25) < 0.03

    def test_registry(self):
        for name in ("conserve", "ampd", "collocated", "full_disagg"):
            assert make_scheduler(name).name == name
