"""Training-substrate behaviour: loss falls, grad-accum equivalence, grad
compression, data-pipeline determinism and sharding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import build_model
from repro.train import (AdamWConfig, DataConfig, SyntheticLM, adamw_init,
                         make_train_step)


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("olmo-1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=8))
    return cfg, model, params, data


def test_loss_decreases(setup):
    cfg, model, params, data = setup
    opt = adamw_init(params)
    step = jax.jit(make_train_step(
        model, AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=40)))
    losses = []
    for i in range(25):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2


def test_grad_accum_matches_full_batch(setup):
    cfg, model, params, data = setup
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    opt = adamw_init(params)
    cfgo = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    p1, _, m1 = make_train_step(model, cfgo, grad_accum=1)(params, opt, batch)
    p2, _, m2 = make_train_step(model, cfgo, grad_accum=4)(params, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
    diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32))))
             for a, b in zip(jax.tree_util.tree_leaves(p1),
                             jax.tree_util.tree_leaves(p2))]
    assert max(diffs) < 3e-2  # same update up to fp tolerance


def test_grad_compression_close_to_exact(setup):
    cfg, model, params, data = setup
    batch = {k: jnp.asarray(v) for k, v in data.batch(1).items()}
    opt = adamw_init(params)
    cfgo = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    _, _, m1 = make_train_step(model, cfgo)(params, opt, batch)
    _, _, m2 = make_train_step(model, cfgo, compress_grads=True)(
        params, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5  # same fwd
    assert abs(float(m1["grad_norm"]) - float(m2["grad_norm"])) \
        < 0.02 * float(m1["grad_norm"]) + 1e-3


def test_data_determinism_and_sharding():
    dc = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    d = SyntheticLM(dc)
    b1, b2 = d.batch(5), d.batch(5)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    # shards are disjoint substreams covering the global batch size
    s0 = SyntheticLM(dc, shard=0, n_shards=2).batch(5)
    s1 = SyntheticLM(dc, shard=1, n_shards=2).batch(5)
    assert s0["tokens"].shape[0] == 4 and s1["tokens"].shape[0] == 4
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    # labels are next-token shifted
    assert np.array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
