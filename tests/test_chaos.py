"""Chaos harness contract: seeded schedules are byte-identical functions of
their seed, the tool-timeout materializer mutates a COPY of the workload,
the placement monitor rejects any admission targeting a dead or quarantined
node at the instant it is published, and a full simulator chaos soak
(kill -> rejoin, quarantine round trip, transfer fault, tool timeout)
completes with streams identical to the fault-free offline replay."""
import dataclasses
from types import SimpleNamespace

import pytest

from repro.chaos import (ChaosEvent, ChaosSchedule, PlacementMonitor,
                         apply_tool_timeouts, check_chaos_invariants,
                         generate_chaos_schedule)
from repro.chaos.schedule import (FAULT_KILL, FAULT_REJOIN, FAULT_SLOWDOWN,
                                  FAULT_SLOWDOWN_END, FAULT_TOOL_TIMEOUT,
                                  FAULT_TRANSFER)
from repro.core.conversation import Conversation, Turn
from repro.core.events import (EV_ADMISSION_ADMIT, EV_ADMISSION_PARK,
                               EV_NODE_FAILURE, EV_NODE_JOIN, EventBus,
                               ServeEvent)
from repro.core.signals import NODE_ACTIVE, NODE_QUARANTINED


# --------------------------------------------------------------------------- #
# schedule generation: pure function of (seed, args)
# --------------------------------------------------------------------------- #
def test_schedule_is_seed_deterministic():
    a = generate_chaos_schedule(42, [1, 2, 3])
    b = generate_chaos_schedule(42, [1, 2, 3])
    assert a.events == b.events
    assert a.to_json() == b.to_json()
    assert a.digest == b.digest


def test_schedule_digest_changes_with_seed():
    digests = {generate_chaos_schedule(s, [1, 2]).digest for s in range(8)}
    assert len(digests) == 8


def test_schedule_structure():
    sched = generate_chaos_schedule(7, [1, 2, 3], n_transfer_faults=2)
    kinds = sched.kinds()
    # guaranteed composition: one kill->rejoin cycle, one slowdown window,
    # the requested transfer faults, one tool timeout
    assert kinds[FAULT_KILL] == 1 and kinds[FAULT_REJOIN] == 1
    assert kinds[FAULT_SLOWDOWN] == 1 and kinds[FAULT_SLOWDOWN_END] == 1
    assert kinds[FAULT_TRANSFER] == 2 and kinds[FAULT_TOOL_TIMEOUT] == 1

    (kill,), (rejoin,) = sched.of_kind(FAULT_KILL), sched.of_kind(FAULT_REJOIN)
    (slow,), (slow_end,) = (sched.of_kind(FAULT_SLOWDOWN),
                            sched.of_kind(FAULT_SLOWDOWN_END))
    assert rejoin.node_id == kill.node_id and rejoin.at_frac > kill.at_frac
    assert slow_end.node_id == slow.node_id
    assert slow_end.at_frac > slow.at_frac and slow.factor > 1.0
    # the kill victim and the slowdown victim differ by construction
    assert kill.node_id != slow.node_id
    # events come time-ordered
    fracs = [e.at_frac for e in sched.events]
    assert fracs == sorted(fracs)


def test_schedule_respects_protected_nodes():
    for seed in range(16):
        sched = generate_chaos_schedule(seed, [0, 1, 2], protected=[0])
        victims = {e.node_id for e in sched.events if e.node_id is not None}
        assert 0 not in victims


def test_schedule_requires_two_eligible_victims():
    with pytest.raises(ValueError, match="fault-eligible"):
        generate_chaos_schedule(1, [1])
    with pytest.raises(ValueError, match="fault-eligible"):
        generate_chaos_schedule(1, [0, 1], protected=[1])


def test_unknown_fault_kind_rejected():
    with pytest.raises(ValueError, match="unknown fault kind"):
        ChaosEvent("power_surge", 0.5, node_id=1)


def test_schedule_json_round_trips_digest():
    sched = generate_chaos_schedule(3, [1, 2])
    clone = ChaosSchedule(
        seed=sched.seed,
        events=tuple(ChaosEvent(**dataclasses.asdict(e))
                     for e in sched.events))
    assert clone.digest == sched.digest


# --------------------------------------------------------------------------- #
# tool-timeout materializer
# --------------------------------------------------------------------------- #
def _convs():
    return [Conversation(cid=0, arrival_s=0.0, turns=[
                Turn(append_tokens=8, output_tokens=4, tool_time_s=0.01),
                Turn(append_tokens=4, output_tokens=4, tool_time_s=0.0)]),
            Conversation(cid=1, arrival_s=0.1, turns=[
                Turn(append_tokens=8, output_tokens=4, tool_time_s=0.0)])]


def test_apply_tool_timeouts_mutates_a_copy():
    convs = _convs()
    sched = generate_chaos_schedule(5, [1, 2])
    deadline = 0.5
    out = apply_tool_timeouts(convs, sched, deadline)
    # the original workload is untouched (same workload feeds the baseline)
    assert all(t.tool_time_s <= 0.01 for c in convs for t in c.turns)
    # the victim's mid-turn tool wait is inflated past the watchdog deadline
    victims = [t for c in out for t in c.turns
               if t.tool_time_s >= 3.0 * deadline]
    assert len(victims) == len(sched.of_kind(FAULT_TOOL_TIMEOUT))


def test_apply_tool_timeouts_needs_a_multi_turn_victim():
    single = [Conversation(cid=0, arrival_s=0.0, turns=[
        Turn(append_tokens=8, output_tokens=4, tool_time_s=0.0)])]
    sched = generate_chaos_schedule(5, [1, 2])
    with pytest.raises(ValueError, match="no multi-turn conversation"):
        apply_tool_timeouts(single, sched, 0.5)


# --------------------------------------------------------------------------- #
# placement monitor: a pure bus subscriber over synthetic lifecycle events
# --------------------------------------------------------------------------- #
class _FakeRuntime:
    """bus + view is the monitor's whole surface — NodeState stand-ins are
    enough to exercise the placement contract without a runtime."""

    def __init__(self):
        self.bus = EventBus()
        self._nodes = {
            1: SimpleNamespace(alive=True, lifecycle=NODE_ACTIVE),
            2: SimpleNamespace(alive=False, lifecycle=NODE_ACTIVE),
            3: SimpleNamespace(alive=True, lifecycle=NODE_QUARANTINED),
        }
        self.view = SimpleNamespace(node=self._nodes.__getitem__)


def test_monitor_accepts_active_and_counts_post_join_admits():
    rt = _FakeRuntime()
    mon = PlacementMonitor(rt)
    rt.bus.publish(ServeEvent(EV_ADMISSION_ADMIT, 1.0, cid=7, node_id=1))
    assert not mon.violations and mon.post_join_admits == {}
    rt.bus.publish(ServeEvent(EV_NODE_JOIN, 2.0, node_id=1,
                              data={"reason": "from_dead"}))
    rt.bus.publish(ServeEvent(EV_ADMISSION_ADMIT, 3.0, cid=8, node_id=1))
    assert mon.post_join_admits == {1: 1}
    assert [m.kind for m in mon.lifecycle_log] == [EV_NODE_JOIN]
    mon.close()


@pytest.mark.parametrize("node_id,why", [(2, "dead"), (3, NODE_QUARANTINED)],
                         ids=["dead", "quarantined"])
def test_monitor_raises_on_bad_placement_target(node_id, why):
    rt = _FakeRuntime()
    mon = PlacementMonitor(rt)
    ev = ServeEvent(EV_ADMISSION_PARK, 1.5, cid=9, node_id=node_id)
    with pytest.raises(AssertionError, match=why):
        rt.bus.publish(ev)
    # the violation is ALSO recorded for the post-run checker
    assert len(mon.violations) == 1 and why in mon.violations[0]
    mon.close()


def test_monitor_recovery_latency_and_availability():
    rt = _FakeRuntime()
    mon = PlacementMonitor(rt)
    rt.bus.publish(ServeEvent(EV_NODE_FAILURE, 2.0, node_id=1))
    rt.bus.publish(ServeEvent(EV_NODE_JOIN, 5.0, node_id=1,
                              data={"reason": "from_dead"}))
    assert mon.recovery_latencies() == [3.0]
    # down [2, 5] of a [0, 10] window -> 70% schedulable
    avail = mon.availability_timeline([1], 0.0, 10.0)
    assert avail[1] == pytest.approx(0.7)
    mon.close()


def test_monitor_unsubscribes_on_close():
    rt = _FakeRuntime()
    mon = PlacementMonitor(rt)
    mon.close()
    rt.bus.publish(ServeEvent(EV_ADMISSION_ADMIT, 1.0, cid=1, node_id=2))
    assert not mon.violations  # no longer listening


# --------------------------------------------------------------------------- #
# invariant checker surfaces the first broken contract
# --------------------------------------------------------------------------- #
def test_checker_names_missing_conversations():
    rt = _FakeRuntime()
    mon = PlacementMonitor(rt)
    gw = SimpleNamespace(streams={}, runtime=rt)
    sched = ChaosSchedule(seed=0, events=())
    convs = _convs()
    with pytest.raises(AssertionError, match="never completed"):
        check_chaos_invariants([], gw, mon, sched, convs, {})
    mon.close()


def test_checker_names_stream_divergence():
    rt = _FakeRuntime()
    mon = PlacementMonitor(rt)
    recs = [SimpleNamespace(cid=c.cid) for c in _convs()]
    gw = SimpleNamespace(streams={(0, 0): [1, 2]}, runtime=rt)
    sched = ChaosSchedule(seed=0, events=())
    with pytest.raises(AssertionError, match="diverged"):
        check_chaos_invariants(recs, gw, mon, sched, _convs(),
                               {(0, 0): [1, 3]})
    mon.close()


# --------------------------------------------------------------------------- #
# end-to-end: the simulator chaos soak (the benchmark's own sim half) holds
# the full contract — completion, stream identity, zero bad placements, a
# kill -> rejoin cycle AND a quarantine round trip in one seeded run
# --------------------------------------------------------------------------- #
def test_sim_chaos_soak_holds_full_contract():
    from benchmarks.chaos_soak import _sim_chaos

    out = _sim_chaos(16, 20260807)
    assert out["all_complete"] and out["streams_identical"]
    assert out["zero_bad_placements"]
    ev = out["evidence"]
    assert ev["n_failures"] >= 1 and ev["n_quarantines"] >= 1
    # every failure AND the quarantine produced a matching rejoin
    assert ev["n_joins"] >= ev["n_failures"] + ev["n_quarantines"]
    assert ev["n_transfer_retries"] >= 1
    assert ev["post_join_admits"]  # the rejoined fleet observably served
    assert all(l > 0 for l in ev["recovery_latencies_s"])
    assert 0.0 < out["decoder_availability_fraction"] <= 1.0
