"""Model-substrate invariants: decode/append-prefill consistency vs full
prefill across EVERY architecture family, MLA absorbed-decode equivalence,
MoE routing behaviour, local-attention equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, get_reduced
from repro.models import build_model

from repro.models.model import merge_decode_cache as merge_caches


def setup(arch, key, S=17):
    cfg = get_reduced(arch)
    m = build_model(cfg)
    params = m.init(key)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (2, S), 0,
                              cfg.vocab_size)
    fe = None
    F = 0
    if cfg.frontend != "none":
        fl = cfg.frontend_len or cfg.encoder_seq
        fe = jax.random.normal(jax.random.fold_in(key, 3),
                               (2, fl, cfg.d_model), cfg.jnp_dtype) * 0.02
        F = cfg.frontend_len if cfg.frontend == "vision" else 0
    return cfg, m, params, toks, fe, F


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_full_prefill(arch, key):
    cfg, m, params, toks, fe, F = setup(arch, key)
    S = toks.shape[1]
    lg_full, _ = m.prefill(params, toks, frontend_embeds=fe)
    _, caches = m.prefill(params, toks[:, :-1], frontend_embeds=fe)
    lg_dec, _ = m.decode_step(params, toks[:, -1], caches,
                              jnp.full((2,), F + S - 1, jnp.int32))
    err = float(jnp.max(jnp.abs(lg_full.astype(jnp.float32)
                                - lg_dec.astype(jnp.float32))))
    assert err < 2e-4, f"{arch}: decode err {err}"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_append_prefill_matches_full(arch, key):
    cfg, m, params, toks, fe, F = setup(arch, key)
    lg_full, _ = m.prefill(params, toks, frontend_embeds=fe)
    _, c1 = m.prefill(params, toks[:, :8], frontend_embeds=fe)
    lg_b, _ = m.prefill(params, toks[:, 8:], caches=c1, start_pos=F + 8)
    err = float(jnp.max(jnp.abs(lg_full.astype(jnp.float32)
                                - lg_b.astype(jnp.float32))))
    assert err < 2e-4, f"{arch}: append err {err}"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_multi_step_decode_consistency(arch, key):
    """Three sequential decode steps == prefill of the same tokens."""
    cfg, m, params, toks, fe, F = setup(arch, key, S=16)
    lg_full, _ = m.prefill(params, toks, frontend_embeds=fe)
    _, caches = m.prefill(params, toks[:, :-3], frontend_embeds=fe)
    pos = F + 13
    for i in range(3):
        lg, ups = m.decode_step(params, toks[:, -3 + i], caches,
                                jnp.full((2,), pos, jnp.int32))
        caches = merge_caches(caches, ups)
        pos += 1
    err = float(jnp.max(jnp.abs(lg_full.astype(jnp.float32)
                                - lg.astype(jnp.float32))))
    assert err < 3e-4, f"{arch}: 3-step decode err {err}"


def test_mla_cache_is_compressed(key):
    """The MLA cache stores (rank + rope) per token, not 2*H*hd."""
    cfg = get_reduced("deepseek-v2-lite-16b")
    m = build_model(cfg)
    params = m.init(key)
    toks = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)
    _, caches = m.prefill(params, toks)
    leaves = jax.tree_util.tree_leaves_with_path(caches)
    names = {str(getattr(p[-1], "key", p[-1])) for p, _ in leaves}
    assert "ckv" in names and "krope" in names
    assert "k" not in names  # no full per-head KV stored
    per_tok = cfg.kv_lora_rank + cfg.qk_rope_dim
    full = 2 * cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
    assert per_tok < full / 3


def test_moe_capacity_drops_tokens(key):
    """With capacity_factor << E/K the dispatch drops overflow tokens
    (standard capacity semantics) but stays finite."""
    from repro.models.moe import apply_moe, moe_skeleton
    from repro.models.layers import init_params
    cfg = dataclasses.replace(get_reduced("llama4-scout-17b-a16e"),
                              capacity_factor=0.25)
    sk = moe_skeleton(cfg)
    params = init_params(sk, key)
    x = jax.random.normal(key, (2, 32, cfg.d_model), cfg.jnp_dtype)
    y = apply_moe(params, cfg, x, group_size=16)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y.astype(jnp.float32)).all())


def test_local_attention_matches_masked_global(key):
    from repro.models.attention import local_attention, online_attention
    B, S, H, D, W = 2, 128, 2, 32, 48
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, D))
    pos = jnp.arange(S)
    a = local_attention(q, k, v, 0, W)
    b = online_attention(q, k, v, pos, pos, causal=True, window=W)
    assert float(jnp.max(jnp.abs(a - b))) < 2e-5


def test_rwkv_state_is_constant_size(key):
    cfg = get_reduced("rwkv6-3b")
    m = build_model(cfg)
    params = m.init(key)
    toks = jax.random.randint(key, (1, 32), 0, cfg.vocab_size)
    _, c8 = m.prefill(params, toks[:, :8])
    _, c32 = m.prefill(params, toks)
    sizes8 = sum(l.size for l in jax.tree_util.tree_leaves(c8))
    sizes32 = sum(l.size for l in jax.tree_util.tree_leaves(c32))
    assert sizes8 == sizes32  # O(1) state regardless of context
