"""Real-engine tests: token-exact equality with a direct model rollout
through slot buffers / padding / masking, multi-turn append correctness,
KV transfer between replicas, and the full EngineServer loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import make_scheduler
from repro.core.metrics import summarize
from repro.engine import EngineServer, ReplicaEngine, bucket_len
from repro.models import build_model
from repro.traces import TraceConfig, generate_trace

from repro.models.model import merge_decode_cache as merge


@pytest.fixture(scope="module")
def qwen():
    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def oracle_rollout(model, params, cfg, prompt, n_steps):
    lg, caches = model.prefill(params, jnp.asarray(prompt)[None])
    toks = [int(jnp.argmax(lg[0, : cfg.vocab_size]))]
    pos = len(prompt)
    for _ in range(n_steps):
        lg, ups = model.decode_step(params, jnp.asarray([toks[-1]]), caches,
                                    jnp.asarray([pos]))
        caches = merge(caches, ups)
        pos += 1
        toks.append(int(jnp.argmax(lg[0, : cfg.vocab_size])))
    return toks


def test_bucket_len():
    assert bucket_len(1) == 32 and bucket_len(33) == 64
    assert bucket_len(4096) == 4096 and bucket_len(5000) == 8192


def test_engine_matches_oracle(qwen):
    cfg, model, params = qwen
    eng = ReplicaEngine(cfg, params, n_slots=4, max_ctx=256)
    slot = eng.kv.acquire()
    prompt = np.arange(11, 48, dtype=np.int32)  # 37 -> bucket 64 (padded)
    tok, _ = eng.prefill_conversation(slot, prompt)
    got = [int(tok)]
    for _ in range(6):
        nt = np.zeros(4, np.int32)
        em = np.zeros(4, bool)
        nt[slot], em[slot] = got[-1], True
        sampled, _ = eng.decode_step_all(nt, em)
        got.append(int(sampled[slot]))
    want = oracle_rollout(model, params, cfg, prompt, 6)
    assert got == want


def test_engine_multiturn_append_matches_oracle(qwen):
    """prefill -> decode -> append-prefill -> decode == oracle over the
    concatenated token stream (ConServe's pinned-tail path)."""
    cfg, model, params = qwen
    eng = ReplicaEngine(cfg, params, n_slots=2, max_ctx=256)
    slot = eng.kv.acquire()
    t1 = np.arange(5, 30, dtype=np.int32)     # 25 tokens
    append = np.arange(100, 117, dtype=np.int32)  # 17 tokens

    tok1, _ = eng.prefill_conversation(slot, t1)
    tok2, _ = eng.append_prefill(slot, append)

    # oracle: exact full prefill over [t1, append]
    full = np.concatenate([t1, append])
    lg, _ = model.prefill(params, jnp.asarray(full)[None])
    want = int(jnp.argmax(lg[0, : cfg.vocab_size]))
    assert int(tok2) == want


def test_kv_transfer_between_replicas_preserves_tokens(qwen):
    """Prefill on replica A, transfer the slot to replica B, continue
    decoding there — tokens must match the single-replica rollout (the
    correctness contract behind ConServe's one-shot transfer)."""
    cfg, model, params = qwen
    a = ReplicaEngine(cfg, params, n_slots=2, max_ctx=256, replica_id=0,
                      role="prefill")
    b = ReplicaEngine(cfg, params, n_slots=2, max_ctx=256, replica_id=1)
    prompt = np.arange(3, 40, dtype=np.int32)
    sa = a.kv.acquire()
    tok, _ = a.prefill_conversation(sa, prompt)
    pkg = a.kv.export_slot(sa)
    a.kv.release(sa)
    sb = b.kv.acquire()
    b.kv.import_slot(sb, pkg)
    got = [int(tok)]
    for _ in range(5):
        nt = np.zeros(2, np.int32)
        em = np.zeros(2, bool)
        nt[sb], em[sb] = got[-1], True
        sampled, _ = b.decode_step_all(nt, em)
        got.append(int(sampled[sb]))
    want = oracle_rollout(model, params, cfg, prompt, 5)
    assert got == want


def test_slot_exhaustion_raises(qwen):
    cfg, model, params = qwen
    eng = ReplicaEngine(cfg, params, n_slots=2, max_ctx=64)
    eng.kv.acquire()
    eng.kv.acquire()
    with pytest.raises(RuntimeError):
        eng.kv.acquire()


def test_engine_server_conserve_end_to_end(qwen):
    cfg, model, params = qwen
    tc = TraceConfig(first_input_median=60, first_input_sigma=0.3,
                     first_input_max=150, append_median=16, append_sigma=0.4,
                     append_max=40, output_median=6, output_sigma=0.5,
                     output_max=12, mean_turns=2.5, max_turns=4,
                     tool_mean_s=0.02)
    trace = generate_trace(6, 3.0, cfg=tc)
    reps = [ReplicaEngine(cfg, params, n_slots=8, max_ctx=512, replica_id=0,
                          role="prefill"),
            ReplicaEngine(cfg, params, n_slots=8, max_ctx=512, replica_id=1),
            ReplicaEngine(cfg, params, n_slots=8, max_ctx=512, replica_id=2)]
    srv = EngineServer(make_scheduler("conserve"), reps)
    recs = srv.serve(trace)
    s = summarize(recs)
    assert s["n_conversations"] == 6
    assert s["kv_transfers_per_conv"] == 1.0  # exactly once, for real
    assert srv.n_transfers == 6
    # occupancy fully drained on every replica
    for r in reps:
        assert not r.kv.active.any()
        assert r.kv.active_kv_tokens == 0
    for st in srv.states.values():
        assert st.active_conversations == 0
