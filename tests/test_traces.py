"""Agentic trace generator matches the paper's workload shape (Fig. 1 /
§5.1 constants)."""
import numpy as np

from repro.traces import TraceConfig, generate_trace, workload_stats


def test_turn1_heavy_turn2_light():
    trace = generate_trace(300, 1.0, TraceConfig(seed=0))
    first = [c.first_input_len for c in trace]
    appends = [t.append_tokens for c in trace for t in c.turns[1:]]
    assert 12_000 < np.mean(first) < 18_000   # tens of thousands (~15k)
    assert np.mean(appends) < 800             # hundreds
    assert np.mean(first) / np.mean(appends) > 20


def test_outputs_high_variance():
    trace = generate_trace(300, 1.0, TraceConfig(seed=1))
    outs = np.array([t.output_tokens for c in trace for t in c.turns])
    assert np.std(outs) > np.mean(outs)  # heavy-tailed


def test_provisioning_stats_near_paper():
    trace = generate_trace(500, 1.0, TraceConfig(seed=2))
    ws = workload_stats(trace)
    assert 13_000 < ws.mean_first_input < 17_000
    assert ws.mean_decoder_volume < 6_000


def test_determinism_and_arrival_processes():
    a = generate_trace(20, 1.5, TraceConfig(seed=9))
    b = generate_trace(20, 1.5, TraceConfig(seed=9))
    assert all(x.first_input_len == y.first_input_len for x, y in zip(a, b))
    sat = generate_trace(10, 2.0, TraceConfig(seed=3),
                         arrival_process="saturation")
    gaps = np.diff([c.arrival_s for c in sat])
    assert np.allclose(gaps, 0.5)
