"""End-to-end behaviour tests for the full system: the paper's headline
results reproduced through the calibrated cluster runtime, and the
cross-layer contract (same scheduler code driving simulator and real
engine)."""
import jax
import numpy as np
import pytest

from repro.cluster import paper_deployment
from repro.configs import get_reduced
from repro.core import make_scheduler
from repro.core.metrics import SLOThresholds, summarize
from repro.engine import EngineServer, ReplicaEngine
from repro.models import build_model
from repro.traces import TraceConfig, generate_trace


def baseline_slo() -> SLOThresholds:
    """Single-request, interference-free baselines (5x multiplier, §5.3)."""
    trace = generate_trace(1, 0.001, TraceConfig(seed=99))
    sim = paper_deployment("conserve")
    sim.submit(trace).run()
    r = sim.results()[0]
    return SLOThresholds(ttfet_s=max(r.ttfet_s, 1e-3),
                         last_tbt_s=max(r.last_turn_tbt_s, 1e-4),
                         e2e_s=max(r.e2e_s, 1e-3))


class TestHeadlineResults:
    """The paper's Q1-Q4, at reproduction scale."""

    @pytest.fixture(scope="class")
    def at_saturation(self):
        trace = generate_trace(100, 1.63, TraceConfig(seed=17),
                               arrival_process="saturation")
        total_tokens = sum(c.total_input_tokens + c.total_output_tokens
                           for c in trace)
        out = {}
        for system in ("conserve", "ampd", "collocated", "full_disagg"):
            sim = paper_deployment(system)
            sim.submit(trace).run()
            out[system] = summarize(sim.results(),
                                    energy_joules=sim.total_energy_j(),
                                    total_tokens=total_tokens)
        return out

    def test_q1_conserve_best_p95_ttfet_among_disagg(self, at_saturation):
        s = at_saturation
        assert s["conserve"]["ttfet_p95"] <= s["ampd"]["ttfet_p95"]
        assert s["conserve"]["ttfet_p95"] < s["full_disagg"]["ttfet_p95"]

    def test_q1_full_disagg_uncompetitive_e2e(self, at_saturation):
        s = at_saturation
        assert s["full_disagg"]["e2e_gmean"] > 2.0 * s["conserve"]["e2e_gmean"]

    def test_q3_ampd_pays_for_wrong_predictions(self, at_saturation):
        s = at_saturation
        # AMPD@10%: worse TTFET and worse energy than ConServe (Fig. 12)
        assert s["ampd"]["ttfet_gmean"] > s["conserve"]["ttfet_gmean"]
        assert s["ampd"]["tokens_per_joule"] < s["conserve"]["tokens_per_joule"]

    def test_q4_heterogeneous_energy_win_latency_flat(self):
        trace = generate_trace(80, 1.63, TraceConfig(seed=19),
                               arrival_process="saturation")
        total = sum(c.total_input_tokens + c.total_output_tokens
                    for c in trace)
        res = {}
        for het in (False, True):
            sim = paper_deployment("conserve", heterogeneous=het)
            sim.submit(trace).run()
            res[het] = summarize(sim.results(),
                                 energy_joules=sim.total_energy_j(),
                                 total_tokens=total)
        gain = res[True]["tokens_per_joule"] / res[False]["tokens_per_joule"]
        assert gain > 1.05  # energy win from capping the memory-bound tail
        assert res[True]["ttfet_p95"] < 1.2 * res[False]["ttfet_p95"]

    def test_q2_conserve_slo_headroom_vs_baselines(self, at_saturation):
        slo = baseline_slo()
        trace = generate_trace(100, 1.63, TraceConfig(seed=17),
                               arrival_process="saturation")
        rates = {}
        for system in ("conserve", "full_disagg"):
            sim = paper_deployment(system)
            sim.submit(trace).run()
            v = slo.violations(sim.results())
            rates[system] = v
        # FullDisagg blows TTFET SLO wholesale; ConServe strictly better
        assert rates["full_disagg"]["ttfet"] > 0.5
        assert rates["conserve"]["ttfet"] < rates["full_disagg"]["ttfet"]


class TestCrossLayerContract:
    def test_same_policy_object_drives_sim_and_engine(self):
        """One scheduler implementation serves both runtimes — the core
        claim that policy is independent of mechanism."""
        tc = TraceConfig(first_input_median=60, first_input_sigma=0.2,
                         first_input_max=120, append_median=12,
                         append_sigma=0.3, append_max=24, output_median=5,
                         output_sigma=0.4, output_max=10, mean_turns=2.0,
                         max_turns=3, tool_mean_s=0.01)
        trace = generate_trace(4, 5.0, cfg=tc)

        sim = paper_deployment("conserve")
        sim.submit(trace).run()
        sim_recs = sim.results()

        cfg = get_reduced("qwen3-0.6b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        reps = [ReplicaEngine(cfg, params, n_slots=6, max_ctx=256,
                              replica_id=0, role="prefill"),
                ReplicaEngine(cfg, params, n_slots=6, max_ctx=256,
                              replica_id=1),
                ReplicaEngine(cfg, params, n_slots=6, max_ctx=256,
                              replica_id=2)]
        srv = EngineServer(make_scheduler("conserve"), reps)
        eng_recs = srv.serve(trace)

        # both runtimes complete everything with exactly one transfer each
        assert len(sim_recs) == len(eng_recs) == 4
        assert all(r.n_kv_transfers == 1 for r in sim_recs)
        assert all(r.n_kv_transfers == 1 for r in eng_recs)
        assert all(r.n_remote_turns == 0 for r in sim_recs + eng_recs)
