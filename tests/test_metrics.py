"""Conversation-level metric definitions (TTFET, last-turn TBT, E2E, SLO)."""
import numpy as np
import pytest

from repro.core.metrics import (ConversationRecord, SLOThresholds, TurnRecord,
                                gmean, p95, per_turn_distributions, summarize)


def rec(arrival=0.0, turns=((1.0, 2.0, 5), (4.0, 6.0, 10))):
    r = ConversationRecord(cid=0, arrival_s=arrival)
    for i, (ft, lt, n) in enumerate(turns):
        r.turns.append(TurnRecord(turn_idx=i, arrival_s=ft - 0.5,
                                  first_token_s=ft, last_token_s=lt,
                                  n_output_tokens=n))
    return r


def test_ttfet_is_final_turn_first_token():
    r = rec()
    assert r.ttfet_s == 4.0  # first token of the LAST turn, from arrival
    assert r.e2e_s == 6.0
    assert r.ttfet_s <= r.e2e_s


def test_last_turn_tbt():
    r = rec()
    assert abs(r.last_turn_tbt_s - (6.0 - 4.0) / 9) < 1e-9


def test_single_token_turn_has_zero_tbt():
    r = rec(turns=((1.0, 1.0, 1),))
    assert r.last_turn_tbt_s == 0.0


def test_slo_violations():
    slo = SLOThresholds(ttfet_s=1.0, last_tbt_s=0.1, e2e_s=2.0)
    ok = rec(turns=((1.0, 2.0, 30),))            # ttfet 1.0 < 5.0
    bad = rec(turns=((9.0, 9.5, 2),))            # ttfet 9.0 > 5.0
    v = slo.violations([ok, bad])
    assert v["ttfet"] == 0.5


def test_summarize_keys_and_energy():
    s = summarize([rec()], energy_joules=100.0, total_tokens=1500)
    assert s["tokens_per_joule"] == 15.0
    assert s["n_conversations"] == 1
    assert s["ttfet_gmean"] == pytest.approx(4.0)


def test_summarize_recovery_keys_always_present():
    # failure-free: keys exist with zeros (stable benchmark schemas)
    s = summarize([rec()])
    assert s["n_recovered"] == 0 and s["n_tool_evictions"] == 0
    assert s["recovery_latency_mean_s"] == 0.0
    assert s["recovery_latency_p95_s"] == 0.0


def test_summarize_recovery_view():
    ok = rec()
    hurt = rec()
    hurt.recovered = True
    hurt.recovery_latency_s = [0.5, 1.5]
    hurt.n_tool_evictions = 1
    s = summarize([ok, hurt])
    assert s["n_recovered"] == 1
    assert s["n_tool_evictions"] == 1
    assert s["recovery_latency_mean_s"] == pytest.approx(1.0)
    assert s["recovery_latency_p95_s"] == pytest.approx(1.45)


def test_per_turn_distributions_sorted():
    d = per_turn_distributions([rec(), rec()])
    assert (np.diff(d["ttft"]) >= 0).all()
    assert (np.diff(d["tbt"]) >= 0).all()


def test_gmean_p95():
    assert gmean([2.0, 8.0]) == pytest.approx(4.0)
    xs = list(np.arange(1, 101, dtype=float))
    assert p95(xs) == pytest.approx(95.05)
