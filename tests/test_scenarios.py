"""Scenario-library contract: every named generator is a pure function of
(name, n, seed, scale) — byte-identical Conversation lists per seed — and
each scenario's structural invariant holds (DAG gating, HITL parks, shared
preambles, engine-scale context bound)."""
import pytest

from repro.core.conversation import Conversation
from repro.traces import (SCENARIOS, make_scenario, supervisor_worker_dag,
                          workload_stats)


@pytest.mark.parametrize("name", sorted(SCENARIOS))
@pytest.mark.parametrize("scale", ["paper", "engine"])
def test_seed_determinism_byte_identical(name, scale):
    a = make_scenario(name, 14, seed=5, scale=scale)
    b = make_scenario(name, 14, seed=5, scale=scale)
    assert a == b  # plain dataclasses: field-for-field identity
    assert len(a) == 14
    assert all(isinstance(c, Conversation) for c in a)
    # a different seed must actually change the workload
    assert make_scenario(name, 14, seed=6, scale=scale) != a


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_engine_scale_fits_test_replicas(name):
    """Engine-scale scenarios must serve on the max_ctx=1024 replicas the
    tests and CI smoke use — peak context bounded."""
    convs = make_scenario(name, 20, seed=1, scale="engine")
    assert max(c.peak_context_tokens() for c in convs) <= 1024
    s = workload_stats(convs)
    assert s.mean_first_input > 0 and s.mean_peak_kv_tokens <= 1024


def test_workload_stats_sane_paper_scale():
    convs = make_scenario("pareto_burst", 30, seed=3, scale="paper")
    s = workload_stats(convs)
    # the §3 regime: first inputs dominate (tens of k), decoder volume O(1k)
    assert s.mean_first_input > 5_000
    assert 0 < s.mean_decoder_volume < s.mean_first_input


def test_supervisor_worker_dag_gating_invariant():
    """A child dispatched from parent turn g can never be ready before the
    parent's cumulative tool time through g has elapsed."""
    convs, edges = supervisor_worker_dag(24, seed=9, scale="paper")
    assert edges, "DAG scenario generated no supervisor->worker edges"
    by = {c.cid: c for c in convs}
    for parent_cid, gate_turn, child_cid in edges:
        parent, child = by[parent_cid], by[child_cid]
        assert 0 <= gate_turn < parent.n_turns
        cum_tool = sum(t.tool_time_s
                       for t in parent.turns[:gate_turn + 1])
        assert child.arrival_s >= parent.arrival_s + cum_tool


def test_hitl_longpark_has_long_parks():
    convs = make_scenario("hitl_longpark", 40, seed=2, scale="paper")
    base = make_scenario("pareto_burst", 40, seed=2, scale="paper")
    longest = max(t.tool_time_s for c in convs for t in c.turns)
    assert longest > 10 * max(t.tool_time_s for c in base for t in c.turns)


def test_shared_preamble_fleet_shares_identities():
    convs = make_scenario("shared_preamble_fleet", 40, seed=4,
                          scale="paper", n_preambles=3)
    ids = [c.preamble_id for c in convs if c.preamble_id is not None]
    assert len(ids) >= 20          # preamble_share=0.8 of 40
    assert 1 < len(set(ids)) <= 3  # distinct shared identities
    assert all(0 < c.preamble_tokens < c.first_input_len
               for c in convs if c.preamble_id is not None)


def test_unknown_scenario_and_scale_raise():
    with pytest.raises(ValueError, match="unknown scenario"):
        make_scenario("definitely_not_a_scenario", 4)
    with pytest.raises(ValueError, match="unknown scale"):
        make_scenario("pareto_burst", 4, scale="galactic")


def test_offsets_combine_without_collision():
    a = make_scenario("pareto_burst", 6, seed=1, scale="engine")
    b = make_scenario("hitl_longpark", 6, seed=1, scale="engine",
                      cid_offset=100, arrival_offset_s=5.0)
    cids = [c.cid for c in a + b]
    assert len(set(cids)) == len(cids)
    assert min(c.arrival_s for c in b) >= 5.0
