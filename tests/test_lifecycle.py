"""Replica lifecycle contract on BOTH backends: a failed node rejoins COLD
(resident counters zero, observed-EMA history discarded, cumulative
counters surviving) and serves again byte-identically; fail -> recover ->
fail cycles are legal while recovering an alive node raises loudly; the
simulator's observed-straggler quarantine round-trips (trip on observed
TBT EMA vs fleet median, drain, rejoin when the observation recovers) with
zero placements on the quarantined node; simulator transfer faults retry
with the engine's exact bounded-backoff contract; and the gateway's
overload error carries the observed queue-depth / drain-rate hints."""
import asyncio

import jax
import pytest

from repro.chaos import PlacementMonitor
from repro.cluster.deployment import build_cluster
from repro.configs import get_reduced
from repro.core import make_scheduler
from repro.core.conversation import Conversation, Turn
from repro.core.events import EV_NODE_JOIN, EV_NODE_QUARANTINE
from repro.core.signals import NODE_ACTIVE
from repro.engine import EngineServer, ReplicaEngine
from repro.models import build_model
from repro.serve import GatewayOverloaded, ServeGateway
from repro.traces import make_scenario


@pytest.fixture(scope="module")
def qwen():
    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _trace(n=4):
    return [Conversation(cid=i, arrival_s=i * 1e-6, turns=[
        Turn(append_tokens=24 + 4 * i, output_tokens=10, tool_time_s=0.05),
        Turn(append_tokens=10 + 2 * i, output_tokens=8, tool_time_s=0.0),
    ]) for i in range(n)]


def _disagg(cfg, params, **kw):
    reps = [ReplicaEngine(cfg, params, n_slots=6, max_ctx=256,
                          replica_id=0, role="prefill"),
            ReplicaEngine(cfg, params, n_slots=3, max_ctx=256,
                          replica_id=1, role="decode"),
            ReplicaEngine(cfg, params, n_slots=3, max_ctx=256,
                          replica_id=2, role="decode")]
    return EngineServer(make_scheduler("conserve"), reps,
                        record_tokens=True, strict_accounting=True, **kw)


@pytest.fixture(scope="module")
def baseline(qwen):
    cfg, _, params = qwen
    srv = _disagg(cfg, params)
    recs = srv.serve(_trace())
    assert len(recs) == 4
    span = max(t.last_token_s for r in recs for t in r.turns)
    return srv.sampled_tokens, span


# --------------------------------------------------------------------------- #
# engine: rejoin is COLD and the rejoined node re-enters service
# --------------------------------------------------------------------------- #
def test_engine_rejoin_is_cold_and_byte_identical(qwen, baseline):
    cfg, _, params = qwen
    tokens, span = baseline
    srv = _disagg(cfg, params)
    srv.fail_replica(1, 0.25 * span)
    srv.recover_replica(1, 0.55 * span)

    # capture the node's state AT the rejoin moment, before the admission
    # pump can land fresh work on it
    at_rejoin = {}
    orig = srv._rejoin_node

    def spy(node_id, t, reason):
        st = srv.states[node_id]
        at_rejoin.update(node_id=node_id, reason=reason, alive=st.alive,
                         lifecycle=st.lifecycle, kv=st.active_kv_tokens,
                         slots=st.used_slots, convs=st.active_conversations,
                         ema=st.observed_tbt_ema_s)
        return orig(node_id, t, reason=reason)

    srv._rejoin_node = spy
    recs = srv.serve(_trace())
    assert len(recs) == 4
    assert srv.sampled_tokens == tokens  # byte-identity across the cycle
    # cold at rejoin: zero resident state, no inherited EMA history
    assert at_rejoin == dict(node_id=1, reason="from_dead", alive=True,
                             lifecycle=NODE_ACTIVE, kv=0, slots=0, convs=0,
                             ema=0.0)
    # and back in the schedulable set at the end
    st = srv.states[1]
    assert st.alive and st.lifecycle == NODE_ACTIVE
    assert any(n.node_id == 1 for n in srv.view.nodes())
    srv.check_accounting()


def test_engine_fail_recover_fail_cycle(qwen, baseline):
    """fail -> recover -> fail -> recover on one replica: per-node
    generations keep the incarnations apart and streams stay identical."""
    cfg, _, params = qwen
    tokens, span = baseline
    srv = _disagg(cfg, params)
    srv.fail_replica(1, 0.2 * span).recover_replica(1, 0.4 * span)
    srv.fail_replica(1, 0.6 * span).recover_replica(1, 0.8 * span)
    recs = srv.serve(_trace())
    assert len(recs) == 4
    assert srv.sampled_tokens == tokens
    assert srv.states[1].alive
    srv.check_accounting()


def test_engine_recover_alive_replica_raises(qwen):
    cfg, _, params = qwen
    srv = _disagg(cfg, params)
    srv.recover_replica(1, 0.0)  # node 1 never died
    with pytest.raises(RuntimeError, match="already alive"):
        srv.serve(_trace(2))


# --------------------------------------------------------------------------- #
# simulator: same rejoin contract, same error text shape
# --------------------------------------------------------------------------- #
def _sim(**kw):
    return build_cluster(make_scheduler("conserve"), n_prefill=1,
                         n_decode=2, strict_accounting=True, **kw)


def _sim_workload(n=10):
    return make_scenario("pareto_burst", n, seed=5, scale="paper")


def _counts(recs):
    return {(r.cid, i): t.n_output_tokens
            for r in recs for i, t in enumerate(r.turns)}


@pytest.fixture(scope="module")
def sim_baseline():
    recs = _sim().serve(_sim_workload())
    span = max(t.last_token_s for r in recs for t in r.turns)
    return _counts(recs), span


def test_sim_revive_is_cold_and_identical(sim_baseline):
    counts, span = sim_baseline
    sim = _sim()
    sim.inject_failure(1, 0.3 * span)
    sim.revive_node(1, 0.55 * span)
    recs = sim.serve(_sim_workload())
    assert _counts(recs) == counts
    node = sim.nodes[1]
    assert node.alive and node.state.lifecycle == NODE_ACTIVE
    assert node.gen >= 1  # the revival opened a new incarnation
    assert any(n.node_id == 1 for n in sim.view.nodes())
    sim.check_accounting()


def test_sim_fail_revive_fail_cycle(sim_baseline):
    counts, span = sim_baseline
    sim = _sim()
    sim.inject_failure(1, 0.2 * span)
    sim.revive_node(1, 0.4 * span)
    sim.inject_failure(1, 0.6 * span)
    sim.revive_node(1, 0.8 * span)
    recs = sim.serve(_sim_workload())
    assert _counts(recs) == counts
    assert sim.nodes[1].gen >= 2
    sim.check_accounting()


def test_sim_revive_alive_node_raises():
    sim = _sim()
    sim.revive_node(1, 0.0)
    with pytest.raises(RuntimeError, match="already alive"):
        sim.serve(_sim_workload(3))


# --------------------------------------------------------------------------- #
# simulator: observed-straggler quarantine round trip
# --------------------------------------------------------------------------- #
def test_sim_quarantine_round_trip_observation_only():
    """A sustained slowdown on one decoder trips the quarantine purely from
    its observed TBT EMA vs the fleet median; while quarantined it takes no
    placements (PlacementMonitor raises otherwise); when the slowdown lifts
    and the EMA decays back under the rejoin threshold it re-enters service
    — and the per-turn counts never change (slow, not wrong)."""
    def mk(**kw):
        return build_cluster(make_scheduler("conserve"), n_prefill=1,
                             n_decode=3, strict_accounting=True, **kw)

    half = 8
    convs = (make_scenario("shared_preamble_fleet", half, seed=2,
                           scale="paper")
             + make_scenario("pareto_burst", half, seed=7, scale="paper",
                             cid_offset=1000, arrival_offset_s=0.05))
    base_recs = mk().serve(convs)
    span = max(t.last_token_s for r in base_recs for t in r.turns)
    counts = _counts(base_recs)

    sim = mk(quarantine_k=3.0, quarantine_window=2)
    sim.inject_slowdown(1, 10.0, at_s=0.30 * span)
    sim.inject_slowdown(1, 1.0, at_s=0.55 * span)
    monitor = PlacementMonitor(sim)
    events = []
    unsub = sim.bus.subscribe(lambda ev: events.append(ev),
                              kinds=[EV_NODE_QUARANTINE, EV_NODE_JOIN])
    recs = sim.serve(convs)
    unsub()
    monitor.close()

    assert _counts(recs) == counts  # slow, never wrong
    q = [ev for ev in events if ev.kind == EV_NODE_QUARANTINE]
    rejoins = [ev for ev in events if ev.kind == EV_NODE_JOIN
               and ev.data.get("reason") == "from_quarantine"]
    assert q and q[0].node_id == 1
    # the trigger's evidence is the observation itself
    assert q[0].data["observed_tbt_ema_s"] > \
        3.0 * q[0].data["fleet_median_tbt_s"]
    assert rejoins and rejoins[0].node_id == 1
    assert rejoins[0].t > q[0].t
    assert not monitor.violations  # nothing placed on the straggler
    assert sim.nodes[1].state.lifecycle == NODE_ACTIVE
    sim.check_accounting()


# --------------------------------------------------------------------------- #
# simulator: injectable transfer faults, engine-parity bounded retry
# --------------------------------------------------------------------------- #
def test_sim_transfer_fault_retries_to_success(sim_baseline):
    counts, _ = sim_baseline
    sim = _sim()
    sim.inject_transfer_faults(1)
    recs = sim.serve(_sim_workload())
    assert sim.n_transfer_retries == 1
    assert _counts(recs) == counts  # faults never change content
    sim.check_accounting()


def test_sim_transfer_fault_exhaustion_raises():
    sim = _sim(max_transfer_retries=2)
    sim.inject_transfer_faults(100)  # every attempt of every binding faults
    with pytest.raises(RuntimeError, match="consecutive attempts"):
        sim.serve(_sim_workload(4))


# --------------------------------------------------------------------------- #
# gateway health surfaces the lifecycle observables
# --------------------------------------------------------------------------- #
def test_gateway_health_surfaces_lifecycle(sim_baseline):
    from repro.serve import serve_scenario_live

    counts, span = sim_baseline
    sim = _sim()
    sim.inject_failure(1, 0.3 * span)
    sim.revive_node(1, 0.55 * span)
    recs, gw, _ = serve_scenario_live(sim, _sim_workload())
    assert _counts(recs) == counts
    h = gw.health()
    assert h["n_node_joins"] >= 1 and h["n_node_quarantines"] == 0
    lifecycles = {st["lifecycle"] for st in h["nodes"].values()}
    assert lifecycles == {NODE_ACTIVE}  # everyone back in service at the end


# --------------------------------------------------------------------------- #
# gateway overload carries observed backoff hints (read from NodeState)
# --------------------------------------------------------------------------- #
def test_gateway_overload_reports_observed_hints(qwen):
    cfg, _, params = qwen
    reps = [ReplicaEngine(cfg, params, n_slots=1, max_ctx=1024,
                          replica_id=i, role="mixed") for i in (0, 1)]
    srv = EngineServer(make_scheduler("conserve"), reps,
                       record_tokens=True, strict_accounting=True)
    burst = make_scenario("pareto_burst", 8, seed=9, scale="engine")
    for c in burst:
        c.arrival_s = 0.0
    extra = make_scenario("pareto_burst", 4, seed=11, scale="engine",
                          cid_offset=100)

    async def run():
        gw = ServeGateway(srv, shed_watermark=0, max_events_per_tick=8)
        gw.start()
        gw.submit(burst)
        err = None
        pending = list(extra)
        for _ in range(2000):
            await asyncio.sleep(0)
            if not pending:
                break
            try:
                gw.submit([pending[0]])
                pending.pop(0)
            except GatewayOverloaded as e:
                err = e
                break
        await gw.drain()
        return err

    err = asyncio.run(run())
    if err is None:
        pytest.skip("burst drained without ever saturating every queue")
    assert err.min_queue_depth is not None and err.min_queue_depth >= 1
    assert err.retry_after_s is not None and err.retry_after_s >= 0.0
    # the hint is derived from observation; with decode activity observed it
    # must be a positive finite backoff
    assert err.retry_after_s < 1e6
