"""Cluster-simulator behaviour: the paper's qualitative results (orderings,
failure modes), fault recovery, stragglers, elasticity, energy accounting."""
import numpy as np
import pytest

from repro.cluster import (A40, Autoscaler, AutoscalerConfig, NodeCostModel,
                           ServedModelProfile, build_cluster, paper_deployment)
from repro.core import make_scheduler
from repro.core.metrics import summarize
from repro.traces import TraceConfig, generate_trace


@pytest.fixture(scope="module")
def trace():
    return generate_trace(60, 1.2, TraceConfig(seed=3))


@pytest.fixture(scope="module")
def results(trace):
    out = {}
    for system in ("conserve", "ampd", "collocated", "full_disagg"):
        sim = paper_deployment(system)
        sim.submit(trace).run()
        out[system] = (summarize(sim.results(),
                                 energy_joules=sim.total_energy_j()), sim)
    return out


class TestPaperOrderings:
    def test_conserve_one_transfer(self, results):
        s, sim = results["conserve"]
        assert s["kv_transfers_per_conv"] == 1.0
        assert s["remote_turns_per_conv"] == 0.0

    def test_full_disagg_worst_ttfet_best_tbt(self, results):
        """Fig. 10's inversion: FullDisagg pays per-turn prefill+transfer
        (worst TTFET/E2E) but its decoders are interference-free (best
        last-turn TBT)."""
        ttfet = {k: v[0]["ttfet_gmean"] for k, v in results.items()}
        tbt = {k: v[0]["last_tbt_gmean"] for k, v in results.items()}
        assert ttfet["full_disagg"] > 2.5 * ttfet["conserve"]
        assert tbt["full_disagg"] < tbt["conserve"]

    def test_conserve_beats_ampd_ttfet(self, results):
        assert results["conserve"][0]["ttfet_gmean"] <= \
            results["ampd"][0]["ttfet_gmean"] + 1e-9

    def test_energy_full_disagg_worst(self, results):
        tpj = {k: v[0]["tokens_per_joule"] for k, v in results.items()}
        assert tpj["full_disagg"] < tpj["conserve"]


class TestBrittleness:
    def test_ampd_degrades_linearly_conserve_flat(self):
        """Fig. 12: gmean latency grows ~monotonically with wrong-prediction
        rate; AMPD@0 == ConServe by construction."""
        trace = generate_trace(60, 1.6, TraceConfig(seed=5),
                               arrival_process="saturation")
        g = {}
        for p in (0.0, 0.1, 0.3, 0.5):
            sim = paper_deployment("ampd", wrong_prediction_rate=p)
            sim.submit(trace).run()
            g[p] = summarize(sim.results())["ttfet_gmean"]
        sim = paper_deployment("conserve")
        sim.submit(trace).run()
        g_cs = summarize(sim.results())["ttfet_gmean"]
        assert abs(g[0.0] - g_cs) < 1e-6  # reduces to ConServe at p=0
        assert g[0.1] > g[0.0] and g[0.3] > g[0.1] and g[0.5] > g[0.3]


class TestFaultTolerance:
    def test_decoder_failure_recovers_by_replay(self):
        trace = generate_trace(20, 1.0, TraceConfig(seed=7, mean_turns=6.0))
        sim = paper_deployment("conserve")
        sim.submit(trace)
        sim.inject_failure(node_id=1, at_s=20.0)
        sim.run()
        recs = sim.results()
        assert len(recs) == 20  # every conversation still completes
        assert any(r.recovered for r in recs)
        assert any("FAILED" in line for line in sim.log)
        # failed node holds nothing; survivors drained
        assert sim.nodes[1].state.active_conversations == 0
        for nid, n in sim.nodes.items():
            if n.alive:
                assert n.state.active_kv_tokens == 0

    def test_decoder_failure_records_recovery_observables(self):
        trace = generate_trace(20, 1.0, TraceConfig(seed=7, mean_turns=6.0))
        sim = paper_deployment("conserve")
        sim.submit(trace)
        sim.inject_failure(node_id=1, at_s=20.0)
        sim.run()
        recs = sim.results()
        s = summarize(recs)
        assert s["n_recovered"] == sum(r.recovered for r in recs) > 0
        # trigger -> resumed decode latency closed for every recovery
        assert all(r.recovery_latency_s for r in recs if r.recovered)
        assert s["recovery_latency_mean_s"] > 0
        # replay compute charged to the prefiller's dedicated observable
        assert sim.nodes[0].state.replayed_prefill_tokens > 0

    def test_two_decoder_failures_replace_around_both_corpses(self):
        """Regression for the re-placement blind spot: with TWO dead
        decoders, drained/parked work and victim re-binds must route around
        both (the old code could silently re-offer onto a dead node, where
        nothing ever pumps). Loud guards now back the invariant."""
        trace = generate_trace(30, 1.2,
                               TraceConfig(seed=21, mean_turns=5.0,
                                           tool_mean_s=4.0))
        sim = paper_deployment("conserve")
        sim.submit(trace)
        sim.inject_failure(node_id=1, at_s=15.0)
        sim.inject_failure(node_id=2, at_s=30.0)
        sim.run()
        recs = sim.results()
        assert len(recs) == 30  # nothing stranded on either corpse
        assert sum(r.recovered for r in recs) > 0
        assert sum("FAILED" in line for line in sim.log) == 2
        # every surviving binding ended on the one healthy decoder
        for nid in (1, 2):
            assert sim.nodes[nid].state.active_conversations == 0
        assert sim.nodes[3].alive

    def test_same_node_double_failure_raises(self):
        trace = generate_trace(5, 1.0, TraceConfig(seed=7))
        sim = paper_deployment("conserve")
        sim.submit(trace)
        sim.inject_failure(node_id=1, at_s=10.0)
        sim.inject_failure(node_id=1, at_s=12.0)
        with pytest.raises(RuntimeError, match="failed twice"):
            sim.run()

    def test_no_healthy_decoder_left_raises(self):
        """Killing the ONLY decoder must fail loudly at re-placement time,
        not park recovery work on the corpse."""
        trace = generate_trace(10, 1.0, TraceConfig(seed=7, mean_turns=4.0))
        sim = build_cluster(make_scheduler("conserve"), n_prefill=1,
                            n_decode=1)
        sim.submit(trace)
        sim.inject_failure(node_id=1, at_s=8.0)
        with pytest.raises(RuntimeError, match="no healthy decoder"):
            sim.run()

    def test_straggler_screening_shifts_bindings(self):
        trace = generate_trace(40, 1.2, TraceConfig(seed=9))
        sched = make_scheduler("conserve", straggler_factor=2.0)
        sim = build_cluster(sched, n_prefill=1, n_decode=3)
        sim.nodes[1].slow_factor = 8.0  # decoder 1 is slow
        sim.submit(trace).run()
        counts = sim.bind_counts
        # the observed-TBT screen deflects new bindings off the straggler
        assert counts.get(1, 0) < counts.get(2, 0)
        assert counts.get(1, 0) < counts.get(3, 0)
        assert len(sim.results()) == 40  # nothing lost


class TestToolWatchdog:
    def test_deadline_evicts_and_tool_return_replays(self):
        """Same watchdog contract as the engine: a tool overrunning the
        deadline loses its KV (freed for parked work); the eventual tool
        return re-admits through deterministic replay."""
        trace = generate_trace(20, 1.5,
                               TraceConfig(seed=31, mean_turns=4.0,
                                           tool_mean_s=10.0))
        sim = paper_deployment("conserve", tool_deadline_s=2.0,
                               tool_timeout_action="evict")
        sim.submit(trace).run()
        recs = sim.results()
        assert len(recs) == 20
        assert sim.n_tool_evictions > 0
        s = summarize(recs)
        assert s["n_tool_evictions"] == sim.n_tool_evictions
        # evicted conversations came back by replay and completed
        evicted = [r for r in recs if r.n_tool_evictions]
        assert evicted and all(r.recovered for r in evicted)
        assert all(r.recovery_latency_s for r in evicted)
        # replay charged to the prefiller
        assert sim.nodes[0].state.replayed_prefill_tokens > 0
        # healthy end state: nothing left resident anywhere
        for n in sim.nodes.values():
            assert n.state.active_kv_tokens == 0
            assert n.state.active_conversations == 0

    def test_deadline_off_by_default(self):
        trace = generate_trace(10, 1.0,
                               TraceConfig(seed=31, tool_mean_s=10.0))
        sim = paper_deployment("conserve")
        sim.submit(trace).run()
        assert sim.n_tool_evictions == 0
        assert not any(r.recovered for r in sim.results())

    def test_fail_action_raises(self):
        trace = generate_trace(5, 1.0,
                               TraceConfig(seed=31, mean_turns=4.0,
                                           tool_mean_s=10.0))
        sim = paper_deployment("conserve", tool_deadline_s=2.0,
                               tool_timeout_action="fail")
        sim.submit(trace)
        with pytest.raises(RuntimeError, match="exceeded the tool deadline"):
            sim.run()


class TestElasticity:
    @staticmethod
    def _idle_cluster(n_decode=3):
        sim = build_cluster(make_scheduler("conserve"), n_prefill=1,
                            n_decode=n_decode)
        cost = NodeCostModel(A40, ServedModelProfile())
        return sim, Autoscaler(sim, cost)

    def test_scale_in_refuses_decoder_with_parked_admissions(self):
        """Regression: scale-in used to flip `alive` directly, stranding any
        conversations parked in the victim's admission queue (a dead queue
        is never pumped). The drain must REFUSE a candidate whose queue
        holds work."""
        from repro.core.runtime import Admission
        sim, scaler = self._idle_cluster()
        victim = min(nid for nid, n in sim.nodes.items()
                     if n.role == "decode")  # idle tie -> first decoder
        sim._admission[victim].push(
            Admission(99, 64, lambda nid: None, kind="arrival"))
        scaler._tick()  # cluster idle: util 0 < low watermark
        assert sim.nodes[victim].alive, (
            "scale-in retired a decoder with parked admissions")
        assert all(n.alive for n in sim.nodes.values())
        assert not any(e[1] == "scale_in" for e in scaler.events)

    def test_scale_in_routes_through_drain_contract(self, monkeypatch):
        """An eligible (empty) victim retires through the SAME
        `_drain_dead_node` path as a failure, not a bare `alive` flip."""
        sim, scaler = self._idle_cluster()
        drained = []
        orig = type(sim)._drain_dead_node

        def spy(self, node_id, now):
            drained.append(node_id)
            return orig(self, node_id, now)

        monkeypatch.setattr(type(sim), "_drain_dead_node", spy)
        scaler._tick()
        assert [e[1] for e in scaler.events].count("scale_in") == 1
        dead = [nid for nid, n in sim.nodes.items() if not n.alive]
        assert dead == drained  # retired exactly once, via the contract

    def test_autoscaler_counts_reserved_kv_tokens(self):
        """Regression: utilization ignored `reserved_kv_tokens`, so a burst
        of admitted-but-unstarted work looked like an idle cluster exactly
        when pressure was building. Reserved tokens alone must trip the
        high watermark."""
        sim, scaler = self._idle_cluster(n_decode=1)
        st = next(n.state for n in sim.nodes.values() if n.role == "decode")
        st.reserved_kv_tokens = int(0.9 * st.kv_capacity_tokens)
        scaler._tick()
        kinds = [e[1] for e in scaler.events]
        assert "scale_out_requested" in kinds, (
            "reserved (admitted-in-flight) KV never registered as pressure")

    def test_tick_rearms_while_admissions_are_parked(self):
        """Regression: the tick re-armed only `if sim._events`, so with an
        empty heap and work parked in admission queues the autoscaler went
        silent forever."""
        from repro.core.runtime import Admission
        sim, scaler = self._idle_cluster()
        assert not sim._events
        some_node = next(iter(sim._admission))
        sim._admission[some_node].push(
            Admission(7, 64, lambda nid: None, kind="arrival"))
        # make every decoder ineligible for scale-in so the tick is a pure
        # observation pass
        for n in sim.nodes.values():
            n.state.active_conversations = 1
        scaler._tick()
        assert sim._events, (
            "autoscaler stopped ticking with conversations still parked")

    def test_autoscaler_adds_decoder_under_pressure(self):
        trace = generate_trace(80, 3.0, TraceConfig(seed=11, tool_mean_s=4.0))
        sched = make_scheduler("conserve")
        sim = build_cluster(sched, n_prefill=1, n_decode=1)
        cost = NodeCostModel(A40, ServedModelProfile())
        scaler = Autoscaler(sim, cost, AutoscalerConfig(
            check_interval_s=5.0, kv_high_watermark=0.5,
            provision_delay_s=10.0)).start()
        sim.submit(trace).run()
        kinds = [e[1] for e in scaler.events]
        assert "scale_out_ready" in kinds
        assert len([n for n in sim.nodes.values() if n.role == "decode"]) > 1
        assert len(sim.results()) == 80


class TestEnergy:
    def test_heterogeneous_improves_tokens_per_joule(self):
        """Fig. 13: capping the decoders leaves latency ~unchanged and
        raises tokens/joule (memory-bound tail absorbs the cap)."""
        trace = generate_trace(50, 1.3, TraceConfig(seed=13))
        out = {}
        for het in (False, True):
            sim = paper_deployment("conserve", heterogeneous=het)
            sim.submit(trace).run()
            out[het] = summarize(sim.results(),
                                 energy_joules=sim.total_energy_j())
        assert out[True]["tokens_per_joule"] > out[False]["tokens_per_joule"]
        assert out[True]["ttfet_p95"] < 1.25 * out[False]["ttfet_p95"]
