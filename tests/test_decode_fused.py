"""Decode-tail rebuild tests: the fused donated in-place decode step vs the
retained `append_step` reference path, the multi-token RAGGED scan loop
(per-slot remaining, mid-chunk freezes), the length-trimmed flash-decode
grid, ctx-trimmed model decode, and end-to-end EngineServer equivalence
between decode modes including staggered-finish agentic traces."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import make_scheduler
from repro.core.conversation import Conversation, Turn
from repro.engine import EngineServer, ReplicaEngine
from repro.kernels import ref
from repro.kernels.decode_attention import flash_decode_attention
from repro.models import build_model
from repro.traces import TraceConfig, generate_trace


@pytest.fixture(scope="module")
def qwen():
    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prefill_two(cfg, params, n_slots=4, max_ctx=256):
    eng = ReplicaEngine(cfg, params, n_slots=n_slots, max_ctx=max_ctx)
    s0, s1 = eng.kv.acquire(), eng.kv.acquire()
    t0, _ = eng.prefill_conversation(s0, np.arange(11, 48, dtype=np.int32))
    t1, _ = eng.prefill_conversation(s1, np.arange(100, 111, dtype=np.int32))
    nt = np.zeros(n_slots, np.int32)
    em = np.zeros(n_slots, bool)
    nt[s0], nt[s1] = int(t0), int(t1)
    em[s0] = em[s1] = True
    return eng, (s0, s1), nt, em


# --------------------------------------------------------------------------- #
# fused in-place decode vs the retained append_step reference path
# --------------------------------------------------------------------------- #
def test_fused_decode_matches_reference_tokens_and_cache(qwen):
    cfg, model, params = qwen
    ref_eng, (s0, s1), nt_r, em = _prefill_two(cfg, params)
    fus_eng, _, nt_f, _ = _prefill_two(cfg, params)
    np.testing.assert_array_equal(nt_r, nt_f)

    ref_toks = {s0: [], s1: []}
    for _ in range(6):
        sampled, _ = ref_eng.decode_step_all_reference(nt_r, em)
        for s in (s0, s1):
            ref_toks[s].append(int(sampled[s]))
            nt_r[s] = int(sampled[s])

    seq, _ = fus_eng.decode_steps(nt_f, em, 6)
    fus_toks = {s: [int(t) for t in seq[:, s]] for s in (s0, s1)}
    assert fus_toks == ref_toks

    # donated in-place scatter must leave byte-identical cache state
    for a, b in zip(jax.tree_util.tree_leaves(ref_eng.kv.caches),
                    jax.tree_util.tree_leaves(fus_eng.kv.caches)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)
    np.testing.assert_array_equal(ref_eng.kv.lengths, fus_eng.kv.lengths)


def test_multi_step_equals_repeated_single_step(qwen):
    cfg, model, params = qwen
    a, (s0, s1), nt_a, em = _prefill_two(cfg, params)
    b, _, nt_b, _ = _prefill_two(cfg, params)

    seq_multi, _ = a.decode_steps(nt_a, em, 5)
    singles = []
    for _ in range(5):
        seq, _ = b.decode_steps(nt_b, em, 1)
        singles.append(seq[0])
        for s in (s0, s1):
            nt_b[s] = int(seq[0, s])
    for i in range(5):
        for s in (s0, s1):
            assert int(seq_multi[i, s]) == int(singles[i][s])


def test_decode_chunk_does_not_advance_inactive_slots(qwen):
    cfg, model, params = qwen
    eng, (s0, s1), nt, em = _prefill_two(cfg, params)
    em[s1] = False  # only s0 decodes
    len1_before = int(eng.kv.lengths[s1])
    cache_row = np.asarray(
        jax.tree_util.tree_leaves(eng.kv.export_slot(s1)["caches"])[0])
    eng.decode_steps(nt, em, 4)
    assert int(eng.kv.lengths[s1]) == len1_before
    cache_row_after = np.asarray(
        jax.tree_util.tree_leaves(eng.kv.export_slot(s1)["caches"])[0])
    np.testing.assert_array_equal(cache_row, cache_row_after)


# --------------------------------------------------------------------------- #
# ragged per-slot chunks: mid-scan freezes, overflow guard, warmup
# --------------------------------------------------------------------------- #
def test_ragged_chunk_matches_per_token_reference_replay(qwen):
    """decode_steps with a per-slot remaining vector must be token- and
    cache-exact against the per-token reference path replayed with the
    same shrinking live mask (slot freezes at step remaining[s])."""
    cfg, model, params = qwen
    fus, (s0, s1), nt_f, em = _prefill_two(cfg, params)
    ref_eng, _, nt_r, _ = _prefill_two(cfg, params)

    rem = np.zeros(fus.kv.n_slots, np.int32)
    rem[s0], rem[s1] = 3, 7
    seq, _ = fus.decode_steps(nt_f, em, rem)
    assert seq.shape[0] == 7  # rows = max(remaining), not the 8-bucket

    ref_toks = {s0: [], s1: []}
    for i in range(7):
        mask = em & (i < rem)
        sampled, _ = ref_eng.decode_step_all_reference(nt_r, mask)
        for s in np.flatnonzero(mask):
            ref_toks[s].append(int(sampled[s]))
            nt_r[s] = int(sampled[s])
    fus_toks = {s: [int(t) for t in seq[: rem[s], s]] for s in (s0, s1)}
    assert fus_toks == ref_toks

    # the short slot advanced by exactly its own remaining, and its cache
    # row is byte-identical to the reference replay's
    np.testing.assert_array_equal(fus.kv.lengths, ref_eng.kv.lengths)
    for a, b in zip(jax.tree_util.tree_leaves(fus.kv.caches),
                    jax.tree_util.tree_leaves(ref_eng.kv.caches)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_ragged_chunk_equals_scalar_when_uniform(qwen):
    """A uniform remaining vector must reproduce the scalar-n contract."""
    cfg, model, params = qwen
    a, (s0, s1), nt_a, em = _prefill_two(cfg, params)
    b, _, nt_b, _ = _prefill_two(cfg, params)
    rem = np.where(em, 5, 0).astype(np.int32)
    seq_v, _ = a.decode_steps(nt_a, em, rem)
    seq_s, _ = b.decode_steps(nt_b, em, 5)
    np.testing.assert_array_equal(seq_v[:, [s0, s1]], seq_s[:, [s0, s1]])
    np.testing.assert_array_equal(a.kv.lengths, b.kv.lengths)


def test_decode_steps_overflow_names_offending_slot(qwen):
    """The per-slot overflow guard must name the slot that would overflow,
    not just report the batch max."""
    cfg, model, params = qwen
    eng, (s0, s1), nt, em = _prefill_two(cfg, params, max_ctx=64)
    eng.kv.lengths[s1] = 62  # 2 tokens of room left
    rem = np.zeros(eng.kv.n_slots, np.int32)
    rem[s0], rem[s1] = 4, 4
    with pytest.raises(RuntimeError, match=rf"slot {s1} at length 62"):
        eng.decode_steps(nt, em, rem)
    # the same call is fine once clamped to the slot's room
    rem[s1] = 2
    eng.decode_steps(nt, em, rem)


def test_decode_steps_rejects_nonpositive_remaining_on_emitting_slot(qwen):
    cfg, model, params = qwen
    eng, (s0, s1), nt, em = _prefill_two(cfg, params)
    rem = np.zeros(eng.kv.n_slots, np.int32)
    rem[s0] = 3  # s1 emits but has remaining 0
    with pytest.raises(ValueError, match=rf"slot\(s\) \[{s1}\]"):
        eng.decode_steps(nt, em, rem)


def test_decode_steps_rejects_over_bucket_remaining(qwen):
    """A per-slot remaining above the largest compiled chunk must raise —
    silently clamping would desync the caller's bookkeeping from
    kv.lengths (the scalar path keeps its historic clamp)."""
    cfg, model, params = qwen
    eng, (s0, s1), nt, em = _prefill_two(cfg, params)
    rem = np.zeros(eng.kv.n_slots, np.int32)
    rem[s0], rem[s1] = 3, 40
    with pytest.raises(ValueError, match=rf"slot {s1} remaining 40"):
        eng.decode_steps(nt, em, rem)


def test_warmup_precompiles_and_separates_compile_time(qwen):
    """warmup_decode pre-builds (chunk, ctx) buckets; compile time lands in
    compile_s and never in the measured decode dt."""
    cfg, model, params = qwen
    eng = ReplicaEngine(cfg, params, n_slots=4, max_ctx=128)
    spent = eng.warmup_decode(chunks=(1, 4), ctx_limits=(64,))
    assert spent > 0
    assert (1, 64) in eng._fused and (4, 64) in eng._fused
    assert eng.compile_s == pytest.approx(spent)

    s0 = eng.kv.acquire()
    t0, _ = eng.prefill_conversation(s0, np.arange(7, 30, dtype=np.int32))
    nt = np.zeros(4, np.int32)
    em = np.zeros(4, bool)
    nt[s0], em[s0] = int(t0), True
    before = eng.compile_s
    _, dt = eng.decode_steps(nt, em, 4)  # hits the pre-warmed (4, 64) bucket
    assert eng.compile_s == before  # no compile charged on a warm bucket
    # a cold bucket compiles into compile_s, and the reported dt stays in
    # the same regime as the warm call (compile is NOT in dt)
    _, dt_cold = eng.decode_steps(nt, em, 2)
    assert eng.compile_s > before
    assert dt_cold < 100 * max(dt, 1e-4)


# --------------------------------------------------------------------------- #
# length-trimmed flash-decode grid vs the jnp oracle
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("lens", [[5, 100, 37], [512, 1, 129], [64, 64, 64],
                                  [512, 512, 512]])
def test_trimmed_flash_decode_ragged(key, lens):
    B, S, H, Hkv, D = 3, 512, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    lens_a = jnp.asarray(lens, jnp.int32)
    want = ref.decode_attention_ref(q, k, v, lens_a)
    got_full = flash_decode_attention(q, k, v, lens_a, block_k=128)
    got_trim = flash_decode_attention(q, k, v, lens_a, block_k=128,
                                      max_len=max(lens))
    assert float(jnp.max(jnp.abs(got_full - want))) < 2e-5
    assert float(jnp.max(jnp.abs(got_trim - want))) < 2e-5


def test_trimmed_flash_decode_len_below_one_block(key):
    """All lengths < block_k: the grid collapses to one KV block."""
    B, S, H, Hkv, D = 2, 1024, 4, 1, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    lens_a = jnp.asarray([7, 130], jnp.int32)
    want = ref.decode_attention_ref(q, k, v, lens_a)
    got = flash_decode_attention(q, k, v, lens_a, block_k=256, max_len=130)
    assert float(jnp.max(jnp.abs(got - want))) < 2e-5


def test_ops_decode_attention_max_len_dispatch(key):
    from repro.kernels import ops
    B, S, H, Hkv, D = 2, 256, 4, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    lens = jnp.asarray([33, 200], jnp.int32)
    a = ops.decode_attention(q, k, v, lens, impl="pallas", max_len=200)
    b = ops.decode_attention(q, k, v, lens, impl="xla", max_len=200)
    c = ops.decode_attention(q, k, v, lens, impl="xla")
    assert float(jnp.max(jnp.abs(a - b))) < 2e-5
    assert float(jnp.max(jnp.abs(b - c))) < 2e-5


def test_model_decode_ctx_limit_matches_untrimmed(qwen):
    """Trimming the cache read to a live-length bound must not change
    logits (padding past kv_lens is fully masked either way)."""
    cfg, model, params = qwen
    eng, (s0, s1), nt, em = _prefill_two(cfg, params)
    lens = jnp.asarray(eng.kv.lengths)
    lg_full, _ = model.decode_step(params, jnp.asarray(nt), eng.kv.caches,
                                   lens, kv_lens=lens)
    lg_trim, _ = model.decode_step(params, jnp.asarray(nt), eng.kv.caches,
                                   lens, kv_lens=lens, ctx_limit=64)
    assert float(jnp.max(jnp.abs(lg_full - lg_trim))) < 1e-4


# --------------------------------------------------------------------------- #
# end-to-end: fused chunked serving == reference single-step serving
# --------------------------------------------------------------------------- #
def test_server_fused_matches_reference_end_to_end(qwen):
    cfg, model, params = qwen
    tc = TraceConfig(first_input_median=50, first_input_sigma=0.3,
                     first_input_max=120, append_median=14, append_sigma=0.4,
                     append_max=32, output_median=6, output_sigma=0.5,
                     output_max=10, mean_turns=2.0, max_turns=3,
                     tool_mean_s=0.02)

    def run(mode):
        trace = generate_trace(4, 3.0, cfg=tc)
        reps = [ReplicaEngine(cfg, params, n_slots=8, max_ctx=512,
                              replica_id=0, role="prefill"),
                ReplicaEngine(cfg, params, n_slots=8, max_ctx=512,
                              replica_id=1)]
        srv = EngineServer(make_scheduler("conserve"), reps,
                           decode_mode=mode, record_tokens=True,
                           strict_accounting=True)
        recs = srv.serve(trace)
        srv.check_accounting()
        return srv, recs

    s_ref, r_ref = run("reference")
    s_fus, r_fus = run("fused")
    assert s_ref.sampled_tokens == s_fus.sampled_tokens
    a = sorted((c.cid, t.turn_idx, t.n_output_tokens)
               for c in r_ref for t in c.turns)
    b = sorted((c.cid, t.turn_idx, t.n_output_tokens)
               for c in r_fus for t in c.turns)
    assert a == b


def _staggered_trace():
    """Four conversations arriving together whose outputs finish 2-20 steps
    apart — the worst case for min-collapsed chunking: slot 0 used to drag
    every chunk down to its tiny remaining.

    All arrivals are at t=0.0 exactly, so every conversation prefills
    (event push order) before the first decode chunk regardless of how
    warm the jit caches are — the queue composition, and hence each
    dispatch's ctx bucket, is identical on every run and in both decode
    modes. Context sizes are chosen to stay inside ONE ctx bucket
    (max length + max chunk < 64) so the trimmed-read width never flips
    with interleaving."""
    outs = (2, 5, 9, 20)
    convs = []
    for i, o in enumerate(outs):
        turns = [Turn(append_tokens=8 + 2 * i, output_tokens=o,
                      tool_time_s=0.0)]
        if i == 1:  # one multi-turn conv exercises chunk-boundary admission
            turns.append(Turn(append_tokens=10, output_tokens=6,
                              tool_time_s=0.0))
        convs.append(Conversation(cid=i, arrival_s=0.0, turns=turns))
    return convs


def test_server_staggered_finish_fused_matches_reference(qwen):
    """Short-output agentic trace with staggered finishes: ragged fused
    serving must produce byte-identical per-(cid, turn) token streams and
    turn records vs decode_mode="reference" — with the jitted prefill ON
    (the default) in both runs, and a third fully-eager run
    (prefill_mode="reference") matching the fused streams too."""
    cfg, model, params = qwen

    def run(mode, prefill_mode=None):
        rep = ReplicaEngine(cfg, params, n_slots=8, max_ctx=256,
                            replica_id=0, role="mixed")
        srv = EngineServer(make_scheduler("conserve"), [rep],
                           decode_mode=mode, record_tokens=True,
                           strict_accounting=True, prefill_mode=prefill_mode)
        recs = srv.serve(_staggered_trace())
        srv.check_accounting()
        return srv, {c.cid: c for c in recs}

    s_ref, r_ref = run("reference")
    s_fus, r_fus = run("fused")
    s_eag, _ = run("reference", prefill_mode="reference")
    assert s_eag.sampled_tokens == s_fus.sampled_tokens
    assert s_ref.sampled_tokens == s_fus.sampled_tokens
    assert sorted(r_ref) == sorted(r_fus)
    for cid in r_ref:
        a = [(t.turn_idx, t.n_output_tokens) for t in r_ref[cid].turns]
        b = [(t.turn_idx, t.n_output_tokens) for t in r_fus[cid].turns]
        assert a == b

    # mid-chunk finishes: on the fused run all four turn-0s decode in one
    # ragged chunk, so their last-token timestamps must interpolate in
    # output order instead of all landing on the chunk boundary
    fin = [r_fus[cid].turns[0].last_token_s for cid in range(4)]
    assert fin[0] < fin[1] < fin[2] < fin[3]


def test_server_rotation_matches_chunk_boundary_staggered(qwen):
    """Continuous rotation (adaptive chunk cuts + mid-tail refill) must
    serve the staggered trace with byte-identical per-(cid, turn) token
    streams and turn records vs the chunk-boundary-only baseline —
    rotation changes WHEN work runs, never WHAT it computes — while
    spending no more masked forwards and no more scan steps."""
    cfg, model, params = qwen

    def run(rotation):
        rep = ReplicaEngine(cfg, params, n_slots=8, max_ctx=256,
                            replica_id=0, role="mixed")
        srv = EngineServer(make_scheduler("conserve"), [rep],
                           record_tokens=True, strict_accounting=True,
                           rotation=rotation)
        recs = srv.serve(_staggered_trace())
        srv.check_accounting()
        return srv, {c.cid: c for c in recs}

    s_rot, r_rot = run(True)
    s_bnd, r_bnd = run(False)
    assert s_rot.sampled_tokens == s_bnd.sampled_tokens
    assert sorted(r_rot) == sorted(r_bnd)
    for cid in r_bnd:
        a = [(t.turn_idx, t.n_output_tokens) for t in r_bnd[cid].turns]
        b = [(t.turn_idx, t.n_output_tokens) for t in r_rot[cid].turns]
        assert a == b
    st_r, st_b = s_rot.states[0], s_bnd.states[0]
    assert st_r.decode_lane_steps_live == st_b.decode_lane_steps_live
    assert st_r.decode_scan_steps <= st_b.decode_scan_steps
    assert st_r.masked_forward_fraction <= st_b.masked_forward_fraction + 1e-9
