"""Zero-dispatch prefill tests: the AOT-compiled donated (append-)prefill
programs vs the retained eager reference path — token and cache parity,
in-slot donated writes, compile-time accounting, warmup, the loud overflow
guard, the Pallas prefill-attention routing, and server-level equivalence
between prefill modes (including the backlog-counter strict-accounting
fix for re-placed turn-1 prefills)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import ConServeScheduler, make_scheduler
from repro.core.conversation import Conversation, Turn
from repro.core.scheduler import Placement
from repro.engine import EngineServer, ReplicaEngine
from repro.models import build_model


@pytest.fixture(scope="module")
def qwen():
    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _cache_equal(a_eng, b_eng, atol=0.0):
    np.testing.assert_array_equal(a_eng.kv.lengths, b_eng.kv.lengths)
    for a, b in zip(jax.tree_util.tree_leaves(a_eng.kv.caches),
                    jax.tree_util.tree_leaves(b_eng.kv.caches)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=atol)


# --------------------------------------------------------------------------- #
# jitted (append-)prefill vs the eager reference oracle
# --------------------------------------------------------------------------- #
def test_jit_prefill_matches_reference_token_and_cache(qwen):
    """One turn-1 prefill per mode: identical next token, byte-identical
    slot cache (the donated in-program scatter must land exactly where the
    host-side write_prefill copy used to)."""
    cfg, model, params = qwen
    toks = np.arange(11, 58, dtype=np.int32)  # 47 -> bucket 64 (padded)
    engs = {m: ReplicaEngine(cfg, params, n_slots=4, max_ctx=256,
                             prefill_mode=m)
            for m in ("jit", "reference")}
    out = {}
    for m, eng in engs.items():
        slot = eng.kv.acquire()
        assert slot == 0
        tok, dt = eng.prefill_conversation(slot, toks)
        out[m] = int(tok)
        assert dt > 0
    assert out["jit"] == out["reference"]
    _cache_equal(engs["jit"], engs["reference"])


def test_jit_append_prefill_matches_reference(qwen):
    """Multi-turn: turn-1 + two appends (prefix crossing a ctx bucket) per
    mode — tokens and final cache identical, and the jitted path must
    never touch export_slot_full (the host-side prefix copy it deletes)."""
    cfg, model, params = qwen
    engs = {m: ReplicaEngine(cfg, params, n_slots=2, max_ctx=256,
                             prefill_mode=m)
            for m in ("jit", "reference")}
    toks1 = np.arange(5, 50, dtype=np.int32)       # 45
    toks2 = np.arange(100, 131, dtype=np.int32)    # 31 -> prefix 45
    toks3 = np.arange(200, 215, dtype=np.int32)    # 15 -> prefix 76 (>64)
    out = {}
    calls = {m: 0 for m in engs}
    for m, eng in engs.items():
        orig = eng.kv.export_slot_full

        def spy(slot, m=m, orig=orig):
            calls[m] += 1
            return orig(slot)

        eng.kv.export_slot_full = spy
        slot = eng.kv.acquire()
        t1, _ = eng.prefill_conversation(slot, toks1)
        t2, _ = eng.append_prefill(slot, toks2)
        t3, _ = eng.append_prefill(slot, toks3)
        out[m] = (int(t1), int(t2), int(t3))
    assert out["jit"] == out["reference"]
    _cache_equal(engs["jit"], engs["reference"])
    assert calls["reference"] == 2  # the oracle still reads the full view
    assert calls["jit"] == 0        # the hot path never materializes it


def test_jit_prefill_then_decode_matches_reference_rollout(qwen):
    """The jitted prefill's cache must feed the fused decode scan exactly
    as the eager one does (prefill -> decode -> append -> decode)."""
    cfg, model, params = qwen

    def roll(mode):
        eng = ReplicaEngine(cfg, params, n_slots=2, max_ctx=256,
                            prefill_mode=mode)
        s = eng.kv.acquire()
        t, _ = eng.prefill_conversation(s, np.arange(7, 44, dtype=np.int32))
        toks = [int(t)]
        nt = np.zeros(2, np.int32)
        em = np.zeros(2, bool)
        em[s] = True
        nt[s] = toks[-1]
        seq, _ = eng.decode_steps(nt, em, 4)
        toks += [int(x) for x in seq[:, s]]
        t2, _ = eng.append_prefill(s, np.arange(60, 75, dtype=np.int32))
        toks.append(int(t2))
        nt[s] = toks[-1]
        seq, _ = eng.decode_steps(nt, em, 3)
        toks += [int(x) for x in seq[:, s]]
        return toks

    assert roll("jit") == roll("reference")


def test_prefill_compile_time_off_the_clock(qwen):
    """A cold bucket's AOT compile lands in compile_s and never in the
    measured dt; a warm bucket charges no compile at all."""
    cfg, model, params = qwen
    from repro.engine import replica as replica_mod
    # isolate from programs other tests may have compiled in-process
    replica_mod._AOT_PREFILL_CACHE.clear()
    eng = ReplicaEngine(cfg, params, n_slots=2, max_ctx=256)
    s = eng.kv.acquire()
    assert eng.compile_s == 0.0
    _, dt_cold = eng.prefill_conversation(s, np.arange(3, 40, dtype=np.int32))
    spent = eng.compile_s
    assert spent > 0                      # bucket 64 compiled...
    assert dt_cold < spent                # ...but never inside measured dt
    eng.kv.release(s)
    s = eng.kv.acquire()
    before = eng.compile_s
    _, dt_warm = eng.prefill_conversation(s, np.arange(9, 50, dtype=np.int32))
    assert eng.compile_s == before        # same bucket: no compile charged
    assert dt_warm < 100 * max(dt_cold, 1e-4)


def test_warmup_prefill_precompiles(qwen):
    """warmup_prefill pre-builds the named (length[, ctx]) buckets so a
    cold replica's first conversations hit warm programs."""
    cfg, model, params = qwen
    from repro.engine import replica as replica_mod
    replica_mod._AOT_PREFILL_CACHE.clear()
    eng = ReplicaEngine(cfg, params, n_slots=2, max_ctx=128)
    spent = eng.warmup_prefill(lengths=(32, 64), ctx_limits=(64,))
    assert spent > 0
    assert eng.compile_s == pytest.approx(spent)
    s = eng.kv.acquire()
    before = eng.compile_s
    eng.prefill_conversation(s, np.arange(4, 30, dtype=np.int32))  # 32-bucket
    eng.append_prefill(s, np.arange(50, 80, dtype=np.int32))  # (32, 64)
    assert eng.compile_s == before  # both hits pre-warmed programs
    # a second replica with the same signature shares the process-wide
    # programs: warming it again compiles nothing
    eng2 = ReplicaEngine(cfg, params, n_slots=2, max_ctx=128)
    assert eng2.warmup_prefill(lengths=(32, 64), ctx_limits=(64,)) == 0.0


def test_prefill_overflow_names_slot(qwen):
    """(Append-)prefill past max_ctx must refuse loudly naming the slot —
    in BOTH modes (the scatter would silently clamp otherwise)."""
    cfg, model, params = qwen
    for mode in ("jit", "reference"):
        eng = ReplicaEngine(cfg, params, n_slots=2, max_ctx=64,
                            prefill_mode=mode)
        s = eng.kv.acquire()
        eng.prefill_conversation(s, np.arange(11, 51, dtype=np.int32))  # 40
        with pytest.raises(RuntimeError, match=rf"slot {s} at length 40"):
            eng.append_prefill(s, np.arange(30, dtype=np.int32))
        with pytest.raises(RuntimeError, match="prefill overflow"):
            eng.prefill_conversation(eng.kv.acquire(),
                                     np.arange(70, dtype=np.int32))


def test_append_near_full_slot_pads_exact_not_clamped(qwen):
    """An append that FITS unpadded but whose length bucket would not
    (prev 40, append 20, max_ctx 64, bucket 32) must fall back to an
    exact-length pad instead of letting the padded scatter clamp into —
    and corrupt — the live prefix rows. Caught by decoding THROUGH the
    appended cache and comparing against the unpadded full-prefill oracle,
    in both prefill modes."""
    cfg, model, params = qwen
    from repro.models.model import merge_decode_cache as merge
    t1 = np.arange(5, 45, dtype=np.int32)       # 40
    app = np.arange(100, 120, dtype=np.int32)   # 20 -> 60 fits, 40+32 > 64

    def oracle():
        full = np.concatenate([t1, app])
        lg, caches = model.prefill(params, jnp.asarray(full)[None])
        toks = [int(jnp.argmax(lg[0, :cfg.vocab_size]))]
        pos = len(full)
        for _ in range(3):
            lg, ups = model.decode_step(params, jnp.asarray([toks[-1]]),
                                        caches, jnp.asarray([pos]))
            caches = merge(caches, ups)
            pos += 1
            toks.append(int(jnp.argmax(lg[0, :cfg.vocab_size])))
        return toks

    want = oracle()
    for mode in ("jit", "reference"):
        eng = ReplicaEngine(cfg, params, n_slots=2, max_ctx=64,
                            prefill_mode=mode)
        s = eng.kv.acquire()
        eng.prefill_conversation(s, t1)
        tok, _ = eng.append_prefill(s, app)
        got = [int(tok)]
        nt = np.zeros(2, np.int32)
        em = np.zeros(2, bool)
        em[s] = True
        for _ in range(3):
            nt[s] = got[-1]
            seq, _ = eng.decode_steps(nt, em, 1)
            got.append(int(seq[0, s]))
        assert got == want, mode


def test_exact_prefill_families_fall_back_to_reference(qwen):
    """Recurrent-block families keep the exact-length eager path no matter
    the requested mode (padding would corrupt recurrent state)."""
    cfg = get_reduced("rwkv6-3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ReplicaEngine(cfg, params, n_slots=2, max_ctx=128,
                        prefill_mode="jit")
    assert not eng._use_jit_prefill()
    s = eng.kv.acquire()
    tok, _ = eng.prefill_conversation(s, np.arange(5, 26, dtype=np.int32))
    assert int(eng.kv.lengths[s]) == 21  # exact, unbucketed
    assert eng.compile_s == 0.0          # nothing AOT-compiled


# --------------------------------------------------------------------------- #
# pallas prefill-attention routing
# --------------------------------------------------------------------------- #
def test_attention_impl_pallas_matches_xla_prefill(qwen):
    """attention_impl="pallas" must route fresh global-attention prefill
    through the flash-prefill kernel token-exactly vs the jnp path, with
    the decode tail still matching afterwards."""
    cfg, model, params = qwen

    def roll(impl):
        eng = ReplicaEngine(cfg, params, n_slots=2, max_ctx=256,
                            attention_impl=impl)
        s = eng.kv.acquire()
        t, _ = eng.prefill_conversation(s, np.arange(3, 45, dtype=np.int32))
        toks = [int(t)]
        t2, _ = eng.append_prefill(s, np.arange(80, 95, dtype=np.int32))
        toks.append(int(t2))
        nt = np.zeros(2, np.int32)
        em = np.zeros(2, bool)
        nt[s], em[s] = toks[-1], True
        seq, _ = eng.decode_steps(nt, em, 3)
        return toks + [int(x) for x in seq[:, s]]

    assert roll("xla") == roll("pallas")


# --------------------------------------------------------------------------- #
# server-level equivalence + backlog accounting
# --------------------------------------------------------------------------- #
def _overload_trace():
    convs = []
    for i in range(6):
        turns = [Turn(append_tokens=20 + 11 * i, output_tokens=3 + i,
                      tool_time_s=0.0)]
        if i % 2 == 0:
            turns.append(Turn(append_tokens=12, output_tokens=4,
                              tool_time_s=0.0))
        convs.append(Conversation(cid=i, arrival_s=0.0, turns=turns))
    return convs


def test_server_prefill_modes_token_identical(qwen):
    """EngineServer(prefill_mode=...) must serve byte-identical per-(cid,
    turn) token streams and turn records across jit / reference prefill —
    the jitted programs change dispatch count, never content."""
    cfg, model, params = qwen

    def run(mode):
        rep = ReplicaEngine(cfg, params, n_slots=4, max_ctx=256,
                            replica_id=0, role="mixed")
        srv = EngineServer(make_scheduler("conserve"), [rep],
                           record_tokens=True, strict_accounting=True,
                           prefill_mode=mode)
        recs = srv.serve(_overload_trace())
        srv.check_accounting()
        return srv, {c.cid: c for c in recs}

    s_jit, r_jit = run("jit")
    s_ref, r_ref = run("reference")
    assert s_jit.sampled_tokens == s_ref.sampled_tokens
    assert sorted(r_jit) == sorted(r_ref)
    for cid in r_ref:
        a = [(t.turn_idx, t.n_output_tokens) for t in r_ref[cid].turns]
        b = [(t.turn_idx, t.n_output_tokens) for t in r_jit[cid].turns]
        assert a == b


class _MoveArrivalsScheduler(ConServeScheduler):
    """Test policy: every parked arrival on node 0 is re-offered to node 1
    (exercises the re-placed turn-1 prefill backlog accounting)."""
    name = "_test_move_arrivals"

    def reoffer_admission(self, cid, node_id, view):
        if node_id == 0:
            return Placement(1)
        return None


def test_replaced_turn1_prefill_keeps_backlog_counter_exact(qwen):
    """A turn-1 prefill parked on one node and re-placed onto another by a
    reoffer policy must carry its queued_prefill_tokens with it the moment
    it moves — strict accounting (which now covers the backlog counter)
    passes at every conversation end."""
    cfg, model, params = qwen
    reps = [ReplicaEngine(cfg, params, n_slots=1, max_ctx=256, replica_id=0,
                          role="mixed"),
            ReplicaEngine(cfg, params, n_slots=4, max_ctx=256, replica_id=1,
                          role="mixed")]
    srv = EngineServer(_MoveArrivalsScheduler(), reps,
                       record_tokens=True, strict_accounting=True)
    recs = srv.serve(_overload_trace())
    assert len(recs) == 6
    srv.check_accounting()
    assert srv.n_deferred_admissions > 0  # parking + re-placement happened
    for st in srv.states.values():
        assert st.queued_prefill_tokens == 0
        assert st.active_kv_tokens == 0 and st.used_slots == 0


def test_sjf_refill_reorders_and_streams_invariant(qwen):
    """conserve_sjf_refill: parked admissions drain shortest-context-first
    (the unit test below asserts the reorder directly), and the served
    token streams are byte-identical to FIFO ConServe — refill order
    changes WHEN work runs, never WHAT it computes."""
    cfg, model, params = qwen

    def run(name):
        rep = ReplicaEngine(cfg, params, n_slots=2, max_ctx=256,
                            replica_id=0, role="mixed")
        srv = EngineServer(make_scheduler(name), [rep],
                           record_tokens=True, strict_accounting=True)
        recs = srv.serve(_overload_trace())
        assert len(recs) == 6
        return srv

    s_fifo = run("conserve")
    s_sjf = run("conserve_sjf_refill")
    assert s_fifo.sampled_tokens == s_sjf.sampled_tokens
    assert s_sjf.n_deferred_admissions > 0  # the queue was exercised


def test_sjf_refill_orders_queue_shortest_context_first():
    """Pure unit test of the select_refill hook: a FIFO queue of cids the
    policy has observed reorders by ascending context; unseen cids keep
    FIFO rank at the tail."""
    from repro.core import ConServeSJFRefillScheduler
    from repro.core.conversation import ConversationView, TurnView
    from repro.core.signals import (ClusterView, NodeState,
                                    PrefillLatencyCurve)
    view = ClusterView({0: NodeState(node_id=0, role="mixed")},
                       PrefillLatencyCurve(0.0, 1e-5, 0.01))
    s = ConServeSJFRefillScheduler()
    s.place_first_prefill(ConversationView(10, 0.0, 300), view)
    s.place_first_prefill(ConversationView(11, 0.0, 40), view)
    s.place_first_prefill(ConversationView(12, 0.0, 120), view)
    # cid 12 accumulates a turn: context 120 + append 50 = 170 observed
    s.place_turn(TurnView(12, 1, 50, 120), 0, view)
    fifo = [10, 11, 12, 99]  # 99 never observed
    assert s.select_refill(0, list(fifo), view) == [11, 12, 10, 99]
    # conversation end forgets the cid (no stale growth)
    s.on_conversation_end(11, view)
    assert s.select_refill(0, list(fifo), view) == [12, 10, 11, 99]
