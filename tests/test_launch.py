"""Launch-layer logic that doesn't need 512 devices: cell support rules and
the HLO collective parser."""
import pytest

from repro.configs import ASSIGNED, SHAPES
from repro.launch.dryrun import parse_collectives
from repro.launch.specs import cell_supported


def test_long_500k_support_rules():
    ok = {a for a in ASSIGNED if cell_supported(a, "long_500k")[0]}
    assert ok == {"rwkv6-3b", "recurrentgemma-9b"}
    # gemma3 is excluded by its published 128k max context, not by attention
    sup, reason = cell_supported("gemma3-12b", "long_500k")
    assert not sup and "max_seq" in reason


def test_all_other_cells_supported():
    for a in ASSIGNED:
        for s in SHAPES:
            if s == "long_500k":
                continue
            assert cell_supported(a, s)[0], (a, s)


def test_collective_parser():
    hlo = """
  %ar = bf16[16,128,512]{2,1,0} all-reduce(bf16[16,128,512] %x), replica_groups={}
  %ag.1 = f32[256,1024]{1,0} all-gather(f32[16,1024] %y), dimensions={0}
  %p = (bf16[8,8]{1,0}, bf16[8,8]{1,0}) all-to-all(%a, %b)
  %cp = u32[4]{0} collective-permute(u32[4] %z)
  %not_a_collective = f32[2]{0} add(f32[2] %a, f32[2] %b)
"""
    totals, counts = parse_collectives(hlo)
    assert counts["all-reduce"] == 1 and totals["all-reduce"] == 16*128*512*2
    assert counts["all-gather"] == 1 and totals["all-gather"] == 256*1024*4
    assert counts["all-to-all"] == 1 and totals["all-to-all"] == 2*8*8*2
    assert counts["collective-permute"] == 1 and totals["collective-permute"] == 16
    assert sum(counts.values()) == 4
