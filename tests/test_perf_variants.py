"""§Perf variant correctness: each hillclimb flag must preserve model
semantics (the optimization rule: keep the speedup, prove it right)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.models import build_model
from repro.train import AdamWConfig, adamw_init, make_train_step


def test_flash_vjp_matches_scan_path_grads(key):
    cfg0 = get_reduced("olmo-1b")
    cfg1 = dataclasses.replace(cfg0, flash_vjp=True)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (2, 64), 0,
                              cfg0.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    m0, m1 = build_model(cfg0), build_model(cfg1)
    params = m0.init(key)
    opt = adamw_init(params)
    p0, _, s0 = make_train_step(m0, AdamWConfig())(params, opt, batch)
    p1, _, s1 = make_train_step(m1, AdamWConfig())(params, opt, batch)
    assert abs(float(s0["loss"]) - float(s1["loss"])) < 1e-6
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            for a, b in zip(jax.tree_util.tree_leaves(p0),
                            jax.tree_util.tree_leaves(p1)))
    assert d < 1e-6  # identical parameter update


def test_int8_kv_decode_close_to_bf16(key):
    """Quantized cache decode stays within quantization tolerance of the
    exact path (int8 with scale 0.05 ⇒ ~2.5% value grid)."""
    cfg0 = get_reduced("qwen3-0.6b")
    cfg1 = dataclasses.replace(cfg0, kv_cache_dtype="int8")
    m0, m1 = build_model(cfg0), build_model(cfg1)
    params = m0.init(key)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (2, 24), 0,
                              cfg0.vocab_size)
    _, c0 = m0.prefill(params, toks[:, :-1])
    lg0, _ = m0.decode_step(params, toks[:, -1], c0,
                            jnp.full((2,), 23, jnp.int32))
    # quantize the same prefilled cache for the int8 model
    from repro.models.attention import quantize_kv
    c1 = jax.tree_util.tree_map_with_path(
        lambda p, l: quantize_kv(l, cfg1)
        if str(getattr(p[-1], "key", p[-1])) in ("k", "v") else l, c0)
    lg1, ups = m1.decode_step(params, toks[:, -1], c1,
                              jnp.full((2,), 23, jnp.int32))
    # logits close in a relative sense; argmax usually preserved
    err = float(jnp.max(jnp.abs(lg0 - lg1)))
    spread = float(jnp.max(jnp.abs(lg0)))
    assert err < 0.15 * spread, f"int8 decode err {err} vs spread {spread}"
    # new cache entries come back quantized
    kleaves = [l for p, l in jax.tree_util.tree_leaves_with_path(ups)
               if str(getattr(p[-1], "key", p[-1])) in ("k", "v")]
    assert all(l.dtype == jnp.int8 for l in kleaves)


def test_rwkv_pad_heads_consistency(key):
    cfg = dataclasses.replace(get_reduced("rwkv6-3b"), rwkv_pad_heads_to=6)
    m = build_model(cfg)
    params = m.init(key)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (2, 17), 0,
                              cfg.vocab_size)
    lg_full, _ = m.prefill(params, toks)
    _, caches = m.prefill(params, toks[:, :-1])
    lg_dec, _ = m.decode_step(params, toks[:, -1], caches,
                              jnp.full((2,), 16, jnp.int32))
    assert float(jnp.max(jnp.abs(lg_full - lg_dec))) < 2e-4
    assert bool(jnp.isfinite(lg_full.astype(jnp.float32)).all())


def test_unrolled_probe_mode_matches_scan(key):
    """Measurement-mode (unrolled layers + block-full attention) must be
    semantically identical to the production scan path."""
    cfg0 = get_reduced("gemma3-12b")
    cfg1 = dataclasses.replace(cfg0, unroll_layers=True, attn_block_full=True)
    m0, m1 = build_model(cfg0), build_model(cfg1)
    params = m0.init(key)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (2, 16), 0,
                              cfg0.vocab_size)
    h0 = m0.hidden(params, toks)
    h1 = m1.hidden(params, toks)
    assert float(jnp.max(jnp.abs(h0.astype(jnp.float32)
                                 - h1.astype(jnp.float32)))) < 2e-4


def test_remat_granularity_preserves_loss(key):
    cfg0 = get_reduced("olmo-1b")
    toks = jax.random.randint(key, (2, 32), 0, cfg0.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    losses = {}
    for gran in ("group", "layer", "both"):
        cfg = dataclasses.replace(cfg0, remat_granularity=gran)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        _, _, s = make_train_step(m, AdamWConfig())(params, opt, batch)
        losses[gran] = float(s["loss"])
    assert max(losses.values()) - min(losses.values()) < 1e-5
