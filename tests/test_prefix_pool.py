"""Shared-prefix KV pool contract: the one pool container both backends age
(observed-reuse eviction, refcount pinning, put-refusal), the engine's
third prefill class (fold pooled rows + delta forward) with byte-identity
pool-on vs pool-off and across eviction schedules, delta-token admission
charging under strict accounting, and the simulator mirror."""
import jax
import numpy as np
import pytest

from repro.cluster import A40, NodeCostModel, ServedModelProfile
from repro.cluster.simulator import ClusterSimulator, SimNode
from repro.configs import get_reduced
from repro.core import make_scheduler
from repro.core.conversation import Conversation, Turn
from repro.core.runtime import PrefixKVPool, prefix_eviction_order
from repro.engine import EngineServer, ReplicaEngine
from repro.models import build_model


@pytest.fixture(scope="module")
def qwen():
    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# --------------------------------------------------------------------------- #
# the pool container (no jax): observed-reuse eviction, pinning, refusal
# --------------------------------------------------------------------------- #
def test_eviction_order_fewest_hits_then_least_recently_hit():
    pool = PrefixKVPool(300)
    for k in ("a", "b", "c"):
        assert pool.put(k, None, 100, 128)
    # b observed twice, a once, c once but hit AFTER a
    pool.get("b"), pool.get("a"), pool.get("b"), pool.get("c")
    order = prefix_eviction_order(pool.entries)
    # fewest hits first (a, c before b); tie a-vs-c broken least-recently-hit
    assert order == ["a", "c", "b"]


def test_put_evicts_by_observed_reuse_never_the_hot_entry():
    pool = PrefixKVPool(200)
    assert pool.put("hot", None, 100, 128)
    assert pool.put("cold", None, 100, 128)
    pool.get("hot")
    assert pool.put("new", None, 100, 128)   # must evict exactly "cold"
    assert pool.contains("hot") and pool.contains("new")
    assert not pool.contains("cold")
    assert pool.n_evictions == 1


def test_pinned_entry_never_evicted_and_put_refuses():
    pool = PrefixKVPool(100)
    assert pool.put("pinned", None, 100, 128)
    pool.get("pinned")
    pool.pin("pinned")
    # a reader holds the rows: eviction must exclude it entirely...
    assert prefix_eviction_order(pool.entries) == []
    # ...and put REFUSES rather than rip rows out from under the reader
    assert not pool.put("other", None, 50, 64)
    assert pool.contains("pinned") and not pool.contains("other")
    assert pool.n_evictions == 0
    pool.unpin("pinned")
    # the moment the reader releases, the same put succeeds
    assert pool.put("other", None, 50, 64)
    assert not pool.contains("pinned")


def test_unpin_without_pin_is_loud():
    pool = PrefixKVPool(100)
    pool.put("k", None, 10, 16)
    with pytest.raises(RuntimeError, match="unpinned more times"):
        pool.unpin("k")


def test_put_semantics_oversize_reput_and_contains_is_side_effect_free():
    pool = PrefixKVPool(100)
    assert not pool.put("huge", None, 101, 128)  # can never fit
    assert pool.put("k", None, 80, 128)
    assert pool.put("k", None, 80, 128)          # re-put: immutable, no-op
    assert pool.n_entries == 1 and pool.pooled_tokens == 80
    pool.contains("k")
    assert pool.total_hits == 0                  # contains never records
    pool.get("k")
    assert pool.total_hits == 1                  # get records the reuse


def test_invalidate_all_keeps_cumulative_counters():
    pool = PrefixKVPool(200)
    pool.put("a", None, 50, 64)
    pool.get("a")
    pool.put("b", None, 160, 192)                # evicts a
    pool.invalidate_all()
    assert pool.n_entries == 0 and pool.pooled_tokens == 0
    assert pool.total_hits == 1 and pool.n_evictions == 1  # history survives
    assert pool.put("a", None, 50, 64)           # reusable after invalidation


def test_prefix_pool_pressure_reads_only_observed_counters():
    """The scheduler-visible pool signal: evictions per recorded hit, built
    purely from counters of events that already happened."""
    from repro.core.scheduler import Scheduler
    from repro.core.signals import ClusterView, NodeState
    st = NodeState(node_id=0, role="prefill")
    view = ClusterView({0: st}, None)
    assert Scheduler.prefix_pool_pressure(view, 0) == 0.0
    st.pooled_prefix_evictions = 3                    # churn before any hit
    assert Scheduler.prefix_pool_pressure(view, 0) == 3.0
    st.pooled_prefix_hits = 6
    assert Scheduler.prefix_pool_pressure(view, 0) == 0.5


# --------------------------------------------------------------------------- #
# engine: byte-identity pool-on vs pool-off, across eviction schedules
# --------------------------------------------------------------------------- #
def _preamble_trace(n=4, preamble=24, n_preambles=1):
    """n conversations sharing preambles round-robin; arrivals spaced 0.3s
    so every prefill (tens of ms) lands before the next arrival — later
    arrivals OBSERVE the pooled preamble at probe time."""
    return [Conversation(
        cid=i, arrival_s=0.3 * i,
        turns=[Turn(append_tokens=preamble + 12 + 2 * i, output_tokens=6,
                    tool_time_s=0.0),
               Turn(append_tokens=8, output_tokens=5, tool_time_s=0.0)],
        preamble_id=i % n_preambles, preamble_tokens=preamble)
        for i in range(n)]


def _serve(cfg, params, trace, pool_tokens, n_preambles=1):
    rep = ReplicaEngine(cfg, params, n_slots=4, max_ctx=256, replica_id=0,
                        role="mixed", prefix_pool_tokens=pool_tokens)
    srv = EngineServer(make_scheduler("conserve"), [rep],
                       record_tokens=True, strict_accounting=True)
    recs = srv.serve(trace)
    assert len(recs) == len(trace)
    srv.check_accounting()
    return srv


def test_stream_byte_identity_pool_on_off_and_under_eviction(qwen):
    """The split, not the pool, fixes the math: pool off, pool with every
    preamble resident, and a thrashing one-entry pool must all emit the
    SAME per-(cid, turn) streams — eviction schedules change timing and
    recompute, never content."""
    cfg, _, params = qwen
    preamble = 24
    trace = _preamble_trace(n=6, preamble=preamble, n_preambles=2)
    off = _serve(cfg, params, trace, pool_tokens=0, n_preambles=2)
    on = _serve(cfg, params, trace, pool_tokens=8 * preamble, n_preambles=2)
    # capacity for ONE preamble: the two identities evict each other
    thrash = _serve(cfg, params, trace, pool_tokens=preamble, n_preambles=2)

    assert on.sampled_tokens == off.sampled_tokens
    assert thrash.sampled_tokens == off.sampled_tokens

    st_off, st_on, st_thr = (s.states[0] for s in (off, on, thrash))
    assert st_off.pooled_prefix_hits == 0 and st_off.pooled_prefix_entries == 0
    assert st_on.pooled_prefix_hits >= 4        # 6 convs, 2 first-touches
    assert st_on.pooled_prefix_entries == 2
    assert st_on.pooled_prefix_evictions == 0
    assert st_thr.pooled_prefix_evictions > 0   # the schedule really thrashed
    # pooled preamble reads are charged to the dedicated observable, never
    # double-counted as prefill compute
    assert st_on.pooled_prefix_tokens == 2 * preamble


class _SpyOffers(EngineServer):
    """Record every arrival admission's (need, charge) at offer time."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.offers = {}

    def _offer(self, node_id, adm, now):
        if adm.kind == "arrival":
            self.offers[adm.cid] = (adm.need_tokens, adm.charge)
        return super()._offer(node_id, adm, now)


def test_strict_accounting_charges_observed_delta_for_parked_pool_hits(qwen):
    """An arrival that OBSERVES a pooled preamble parks charging only its
    delta tokens as prefill backlog (need_tokens stays the full context —
    the slot still lands all of it). strict_accounting reconciles the
    parked sum against queued_prefill_tokens at every event, so a full-token
    charge anywhere in the parked interval would fail the serve itself."""
    cfg, _, params = qwen
    preamble = 24
    # cid 0 populates the pool, then holds the ONLY slot in a 2s tool wait;
    # cids 1-2 arrive mid-wait: pool probe hits, admission parks
    trace = [Conversation(cid=0, arrival_s=0.0, turns=[
                 Turn(append_tokens=preamble + 12, output_tokens=6,
                      tool_time_s=2.0),
                 Turn(append_tokens=8, output_tokens=5, tool_time_s=0.0)],
                 preamble_id=0, preamble_tokens=preamble)]
    trace += [Conversation(cid=i, arrival_s=1.0 + 1e-3 * i, turns=[
                  Turn(append_tokens=preamble + 10 + 2 * i, output_tokens=5,
                       tool_time_s=0.0)],
                  preamble_id=0, preamble_tokens=preamble)
              for i in (1, 2)]
    rep = ReplicaEngine(cfg, params, n_slots=1, max_ctx=256, replica_id=0,
                        role="mixed", prefix_pool_tokens=4 * preamble)
    srv = _SpyOffers(make_scheduler("conserve"), [rep],
                     record_tokens=True, strict_accounting=True)
    recs = srv.serve(trace)
    assert len(recs) == 3 and all(s.done for s in srv.sessions.values())
    assert srv.n_deferred_admissions >= 2       # both hits really parked

    need0, charge0 = srv.offers[0]
    assert charge0 == need0 == preamble + 12    # cold populate: full charge
    for i in (1, 2):
        need, charge = srv.offers[i]
        assert need == preamble + 10 + 2 * i    # fit ask: full context
        assert charge == need - preamble        # backlog charge: delta only
    assert srv.states[0].pooled_prefix_hits >= 2
    srv.check_accounting()


# --------------------------------------------------------------------------- #
# simulator mirror: identity keys, delta charge, same eviction aging
# --------------------------------------------------------------------------- #
def _sim(pool_tokens, trace):
    cost = NodeCostModel(A40, ServedModelProfile())
    nodes = [SimNode(node_id=0, role="prefill", cost=cost,
                     prefix_pool_tokens=pool_tokens),
             SimNode(node_id=1, role="decode", cost=cost)]
    sim = ClusterSimulator(make_scheduler("conserve"), nodes)
    recs = sim.serve(trace)
    assert all(s.done for s in sim.sessions.values())
    return sim, recs


def test_sim_pool_mirror_hits_and_output_parity():
    trace = _preamble_trace(n=6, preamble=24, n_preambles=1)
    off, off_recs = _sim(0, trace)
    on, on_recs = _sim(96, trace)
    pf = on.nodes[0].state
    assert pf.pooled_prefix_hits == 5           # first populates, rest hit
    assert pf.pooled_prefix_entries == 1
    assert pf.pooled_prefix_tokens == 24
    assert off.nodes[0].state.pooled_prefix_hits == 0
    # the pool changes prefill COST, never outcomes: same tokens decoded
    per_cid = lambda recs: {  # noqa: E731
        r.cid: [t.n_output_tokens for t in r.turns] for r in recs}
    assert per_cid(on_recs) == per_cid(off_recs)
    # a pooled hit shortens turn-1 prefill: total prefiller busy time drops
    assert on.nodes[0].busy_s < off.nodes[0].busy_s


def test_sim_pool_thrash_evicts_but_completes():
    trace = _preamble_trace(n=6, preamble=24, n_preambles=2)
    sim, recs = _sim(24, trace)                 # room for ONE identity
    pf = sim.nodes[0].state
    assert pf.pooled_prefix_evictions > 0
    assert len(recs) == 6
