"""Conversation-session runtime contract tests: the ServeSession state
machine, admission-queue backpressure under overload on BOTH backends
(EngineServer and ClusterSimulator) through the shared Runtime protocol with
unmodified scheduler policy classes, token-stream invariance across
admission orderings, observable/ground-truth accounting reconciliation, the
scheduler re-offer hook, and the selectable decode attention kernel."""
import jax
import numpy as np
import pytest

from repro.cluster import A40, NodeCostModel, ServedModelProfile
from repro.cluster.simulator import ClusterSimulator, SimNode
from repro.configs import get_reduced
from repro.core import make_scheduler
from repro.core.conserve import ConServeScheduler
from repro.core.conversation import Conversation, Turn
from repro.core.runtime import (DECODING, DONE, PREFILLING, QUEUED, Runtime,
                                ServeSession, TOOL_WAIT)
from repro.core.scheduler import Placement
from repro.engine import EngineServer, ReplicaEngine
from repro.models import build_model
from repro.traces import TraceConfig, generate_trace

OVERLOAD_TRACE = TraceConfig(seed=11, first_input_median=30,
                             first_input_sigma=0.3, first_input_max=60,
                             append_median=10, append_sigma=0.3,
                             append_max=20, output_median=6, output_sigma=0.5,
                             output_max=12, mean_turns=2.0, max_turns=3,
                             tool_mean_s=0.0)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _overload_trace(n):
    # arrivals packed at the head: all n conversations are concurrently live
    return generate_trace(n, 1e9, cfg=OVERLOAD_TRACE,
                          arrival_process="saturation")


# --------------------------------------------------------------------------- #
# ServeSession state machine
# --------------------------------------------------------------------------- #
def test_session_legal_lifecycle_and_dwell_times():
    s = ServeSession(cid=1, arrival_s=1.0)
    assert s.state == QUEUED
    s.transition(PREFILLING, 2.0)
    s.transition(DECODING, 3.0)
    s.transition(TOOL_WAIT, 4.5)
    s.transition(PREFILLING, 5.0)
    s.transition(DECODING, 5.5)
    s.transition(DONE, 6.0)
    assert s.done
    assert s.queue_wait_s == pytest.approx(1.0)
    assert s.time_in(DECODING) == pytest.approx(1.5 + 0.5)
    assert s.time_in(TOOL_WAIT) == pytest.approx(0.5)


def test_session_illegal_transition_raises():
    s = ServeSession(cid=2, arrival_s=0.0)
    with pytest.raises(RuntimeError, match="illegal session transition"):
        s.transition(TOOL_WAIT, 1.0)  # QUEUED -> TOOL_WAIT is not a thing
    s.transition(PREFILLING, 1.0)
    s.transition(DECODING, 2.0)
    s.transition(DONE, 3.0)
    with pytest.raises(RuntimeError):
        s.transition(QUEUED, 4.0)  # DONE is terminal
    # failure recovery may rewind explicitly
    s.transition(PREFILLING, 5.0, force=True)
    assert s.state == PREFILLING


def test_requeue_from_parkable_stages_is_legal():
    """Any stage that needs capacity on a full node may park: QUEUED is
    re-enterable from PREFILLING (deferred one-shot binding) and TOOL_WAIT
    (deferred remote turn). DECODING never parks — it holds its slot."""
    s = ServeSession(cid=3, arrival_s=0.0)
    s.transition(PREFILLING, 1.0)
    s.transition(QUEUED, 2.0)       # decoder full at bind time
    s.transition(DECODING, 3.0)
    s.transition(TOOL_WAIT, 4.0)
    s.transition(QUEUED, 5.0)       # remote node full at turn arrival
    s.transition(DECODING, 6.0)
    s.transition(DONE, 7.0)
    # 1s initial (arrival->prefill) + 1s at bind + 1s at the remote turn
    assert s.queue_wait_s == pytest.approx(3.0)


def test_forced_rewind_requeues_from_decoding():
    """Failure recovery rewinds DECODING -> QUEUED (illegal normally: a
    decoding conversation holds its slot) under force, and the session can
    then re-run the whole admission/prefill/decode lifecycle."""
    s = ServeSession(cid=10, arrival_s=0.0)
    s.transition(PREFILLING, 1.0)
    s.transition(DECODING, 2.0)
    with pytest.raises(RuntimeError, match="illegal session transition"):
        s.transition(QUEUED, 3.0)
    s.transition(QUEUED, 3.0, force=True)
    assert s.state == QUEUED
    s.transition(PREFILLING, 4.0)
    s.transition(DECODING, 5.0)
    s.transition(DONE, 6.0)
    # both lives are measurements: 1s arrival wait + 1s recovery requeue
    assert s.queue_wait_s == pytest.approx(2.0)
    assert s.time_in(DECODING) == pytest.approx(1.0 + 1.0)
    assert s.time_in(PREFILLING) == pytest.approx(1.0 + 1.0)


def test_forced_rewind_is_append_only_history():
    """A rewind APPENDS to history — the pre-failure segments stay, so
    time_in keeps counting work that really happened before the failure."""
    s = ServeSession(cid=11, arrival_s=0.0)
    s.transition(PREFILLING, 1.0)
    s.transition(DECODING, 2.0)
    n = len(s.history)
    s.transition(QUEUED, 3.0, force=True)
    assert len(s.history) == n + 1
    assert s.history[-2] == (DECODING, 2.0)  # pre-failure segment intact


def test_forced_rewind_clamps_timestamps_monotone():
    """A failure can interleave with a completion stamped at a logically
    LATER time (e.g. a staged decode whose transition carries its future
    prefill-completion time). The rewind stamp clamps to the history tail
    so every dwell stays a non-negative measurement."""
    s = ServeSession(cid=12, arrival_s=0.0)
    s.transition(PREFILLING, 1.0)
    s.transition(DECODING, 5.0)        # stamped at a future logical time
    s.transition(QUEUED, 4.0, force=True)  # failure observed at t=4 < 5
    assert s.history[-1] == (QUEUED, 5.0)  # clamped, not rewound in time
    assert all(t1 >= t0 for (_, t0), (_, t1)
               in zip(s.history, s.history[1:]))
    s.transition(PREFILLING, 4.5)      # later stamps keep clamping forward
    assert s.history[-1][1] == 5.0
    assert s.queue_wait_s == pytest.approx(1.0)  # only the arrival wait


# --------------------------------------------------------------------------- #
# SlotKVCache misuse stays loud (and diagnostic)
# --------------------------------------------------------------------------- #
def test_acquire_error_names_replica_occupancy_and_tokens(qwen):
    cfg, model, params = qwen
    eng = ReplicaEngine(cfg, params, n_slots=2, max_ctx=128, replica_id=7)
    s0 = eng.kv.acquire()
    eng.prefill_conversation(s0, np.arange(5, 25, dtype=np.int32))
    eng.kv.acquire()
    live = eng.kv.active_kv_tokens
    with pytest.raises(RuntimeError,
                       match=rf"replica 7: 2/2 slots active, {live} live"):
        eng.kv.acquire()


# --------------------------------------------------------------------------- #
# the shared Runtime protocol
# --------------------------------------------------------------------------- #
def test_both_backends_implement_runtime(qwen):
    cfg, model, params = qwen
    srv = EngineServer(make_scheduler("conserve"),
                       [ReplicaEngine(cfg, params, n_slots=4, max_ctx=256,
                                      replica_id=0, role="mixed")])
    nodes = [SimNode(node_id=0, role="prefill",
                     cost=NodeCostModel(A40, ServedModelProfile())),
             SimNode(node_id=1, role="decode",
                     cost=NodeCostModel(A40, ServedModelProfile()))]
    sim = ClusterSimulator(make_scheduler("conserve"), nodes)
    assert isinstance(srv, Runtime) and isinstance(sim, Runtime)
    # the contract is served by the SAME unmodified policy class
    assert type(srv.sched) is ConServeScheduler
    assert type(sim.sched) is ConServeScheduler
    for r in (srv, sim):
        assert callable(r.submit) and callable(r.run) and callable(r.results)


# --------------------------------------------------------------------------- #
# overload: 2x more concurrent conversations than decoder KV slots
# --------------------------------------------------------------------------- #
def _serve_engine(cfg, params, n_convs, n_slots, mode="fused"):
    rep = ReplicaEngine(cfg, params, n_slots=n_slots, max_ctx=256,
                        replica_id=0, role="mixed")
    srv = EngineServer(make_scheduler("conserve"), [rep], decode_mode=mode,
                       record_tokens=True, strict_accounting=True)
    recs = srv.serve(_overload_trace(n_convs))
    return srv, recs


def test_engine_overload_completes_with_backpressure(qwen):
    cfg, model, params = qwen
    n_convs, n_slots = 6, 3  # 2x oversubscribed
    srv, recs = _serve_engine(cfg, params, n_convs, n_slots)
    assert len(recs) == n_convs          # no "no free KV slots" crash
    assert all(s.done for s in srv.sessions.values())
    assert srv.n_deferred_admissions >= n_convs - n_slots
    waits = srv.queue_waits()
    assert sum(w > 0 for w in waits.values()) >= n_convs - n_slots
    # backpressure drained completely: no parked work, no held slots
    st = srv.states[0]
    assert st.queued_conversations == 0
    assert st.used_slots == 0 and st.active_kv_tokens == 0
    srv.check_accounting()


def test_engine_overload_streams_invariant_across_admission_orderings(qwen):
    """Per-(cid, turn) token streams must be identical no matter how
    admission interleaves conversations: oversubscribed vs unconstrained
    slots, and fused vs reference decode under overload."""
    cfg, model, params = qwen
    n = 6
    srv_tight, _ = _serve_engine(cfg, params, n, 3)
    srv_wide, _ = _serve_engine(cfg, params, n, 8)
    srv_ref, _ = _serve_engine(cfg, params, n, 3, mode="reference")
    assert srv_tight.sampled_tokens == srv_wide.sampled_tokens
    assert srv_tight.sampled_tokens == srv_ref.sampled_tokens
    # only the oversubscribed runs ever deferred an admission (structural,
    # not timing-dependent: 6 concurrent conversations vs 3 slots)
    assert srv_wide.n_deferred_admissions == 0
    assert srv_tight.n_deferred_admissions > 0
    assert srv_ref.n_deferred_admissions > 0
    assert srv_tight.states[0].queued_conversations == 0


def test_engine_overload_disaggregated_one_shot_preserved(qwen):
    """Deferred one-shot bindings still transfer exactly once (ConServe's
    invariant survives backpressure), and the prefill stage keeps flowing
    while bindings wait."""
    cfg, model, params = qwen
    n_convs = 5
    reps = [ReplicaEngine(cfg, params, n_slots=4, max_ctx=256,
                          replica_id=0, role="prefill"),
            ReplicaEngine(cfg, params, n_slots=2, max_ctx=256, replica_id=1)]
    srv = EngineServer(make_scheduler("conserve"), reps,
                       strict_accounting=True)
    recs = srv.serve(_overload_trace(n_convs))
    assert len(recs) == n_convs
    assert all(r.n_kv_transfers == 1 for r in recs)
    assert all(r.n_remote_turns == 0 for r in recs)
    assert srv.n_deferred_admissions > 0
    assert any(w > 0 for w in srv.queue_waits().values())
    srv.check_accounting()


def test_sim_overload_completes_with_backpressure():
    model = ServedModelProfile()
    nodes = [SimNode(node_id=0, role="prefill",
                     cost=NodeCostModel(A40, model))]
    nodes += [SimNode(node_id=i, role="decode",
                      cost=NodeCostModel(A40, model), n_slots=2)
              for i in (1, 2)]
    sim = ClusterSimulator(make_scheduler("conserve"), nodes)
    trace = generate_trace(8, 1e9,  # 2x the 4 declared decoder slots
                           TraceConfig(seed=5, mean_turns=3.0,
                                       tool_mean_s=6.0),
                           arrival_process="saturation")
    recs = sim.serve(trace)
    assert len(recs) == 8
    assert all(s.done for s in sim.sessions.values())
    assert any(w > 0 for w in sim.queue_waits().values())
    for n in sim.nodes.values():
        assert n.state.queued_conversations == 0
        assert n.state.used_slots == 0
        assert n.state.active_kv_tokens == 0
        assert n.state.reserved_kv_tokens == 0
    # conversations never exceeded the declared slots at any decoder
    assert all(r.n_kv_transfers == 1 for r in recs)


def test_sim_headroom_backpressure_without_slot_limit():
    """Even with unbounded slots, a node's declared KV-token capacity is
    respected: admissions defer until headroom frees instead of silently
    overcommitting (the old divergence)."""
    model = ServedModelProfile()
    cost = NodeCostModel(A40, model)
    nodes = [SimNode(node_id=0, role="prefill", cost=cost),
             SimNode(node_id=1, role="decode", cost=cost)]
    sim = ClusterSimulator(make_scheduler("conserve"), nodes)
    cap = nodes[1].state.kv_capacity_tokens
    # each conversation holds ~cap/3 KV for a long tool wait: only 3 fit at
    # once, so half of the 6 concurrent bindings must defer on headroom
    first = int(cap / 3.05)
    trace = [Conversation(cid=i, arrival_s=i * 1e-6, turns=[
        Turn(append_tokens=first, output_tokens=40, tool_time_s=200.0),
        Turn(append_tokens=100, output_tokens=40, tool_time_s=0.0)])
        for i in range(6)]
    peak = {"kv": 0}
    orig = ClusterSimulator._iterate

    def spy(self, node):
        peak["kv"] = max(peak["kv"], nodes[1].state.active_kv_tokens)
        return orig(self, node)

    ClusterSimulator._iterate = spy
    try:
        recs = sim.serve(trace)
    finally:
        ClusterSimulator._iterate = orig
    assert len(recs) == 6
    assert peak["kv"] <= cap
    assert any(w > 0 for w in sim.queue_waits().values())


# --------------------------------------------------------------------------- #
# continuous decode rotation: mid-chunk refill from the admission queues
# --------------------------------------------------------------------------- #
def _staggered_overload(n):
    """Single-turn conversations with staggered outputs, arrivals packed at
    the head — early finishes strand lanes under chunk-boundary admission
    while the queue of parked conversations supplies mid-tail refills."""
    outs = (2, 5, 9, 14, 20, 26, 32, 40)
    return [Conversation(cid=i, arrival_s=i * 1e-9, turns=[
        Turn(append_tokens=10 + (i % 4) * 2,
             output_tokens=outs[i % len(outs)], tool_time_s=0.0)])
        for i in range(n)]


def test_rotation_refills_mid_tail_streams_match_chunk_boundary(qwen):
    """Rotation on vs off over the same staggered overload: byte-identical
    per-(cid, turn) streams (rotation changes WHEN work runs, never WHAT it
    computes), strictly fewer scan steps for the same live tokens, lower
    masked-forward fraction, higher lane occupancy."""
    cfg, model, params = qwen

    def run(rotation):
        rep = ReplicaEngine(cfg, params, n_slots=4, max_ctx=256,
                            replica_id=0, role="mixed")
        srv = EngineServer(make_scheduler("conserve"), [rep],
                           record_tokens=True, strict_accounting=True,
                           rotation=rotation)
        recs = srv.serve(_staggered_overload(10))
        assert len(recs) == 10
        assert all(s.done for s in srv.sessions.values())
        srv.check_accounting()
        return srv

    rot, bound = run(True), run(False)
    assert rot.sampled_tokens == bound.sampled_tokens
    assert rot.n_deferred_admissions > 0  # the queue supplied the rotation
    st_r, st_b = rot.states[0], bound.states[0]
    # live lane-steps == decoded tokens: identical by construction
    assert st_r.decode_lane_steps_live == st_b.decode_lane_steps_live
    # mid-tail refill reclaims masked/idle lanes: fewer scan steps for the
    # same tokens (structural counters — no wall-time flakiness)
    assert st_r.decode_scan_steps < st_b.decode_scan_steps
    assert st_r.masked_forward_fraction <= st_b.masked_forward_fraction
    assert st_r.slot_busy_fraction > st_b.slot_busy_fraction


def test_select_refill_reorders_but_streams_invariant(qwen):
    """A scheduler that scrambles the refill order admits parked work in a
    different order, yet every per-(cid, turn) token stream is byte-equal
    to the FIFO run's — acceptance: streams are refill-order independent."""
    cfg, model, params = qwen

    class Scrambling(ConServeScheduler):
        name = "conserve_scrambling"

        def __init__(self):
            super().__init__()
            self.reordered = 0

        def select_refill(self, node_id, waiting, view):
            if len(waiting) > 1:
                self.reordered += 1
                return list(reversed(waiting))
            return None

    def run(sched):
        rep = ReplicaEngine(cfg, params, n_slots=3, max_ctx=256,
                            replica_id=0, role="mixed")
        srv = EngineServer(sched, [rep], record_tokens=True,
                           strict_accounting=True)
        recs = srv.serve(_staggered_overload(9))
        assert len(recs) == 9
        assert all(s.done for s in srv.sessions.values())
        return srv

    fifo = run(make_scheduler("conserve"))
    sched = Scrambling()
    lifo = run(sched)
    assert sched.reordered > 0  # the refill order really did differ
    assert fifo.sampled_tokens == lifo.sampled_tokens


def test_conserve_rebalance_drains_parked_bindings_vs_fifo():
    """conserve_rebalance (occupancy-aware reoffer): one-shot KV bindings
    parked on a saturated decoder re-offer to the decoder with the most
    observed KV headroom instead of waiting FIFO behind the busy decoder's
    own releases — completing on the spare decoder with less queue wait."""
    model = ServedModelProfile()
    cost = NodeCostModel(A40, model)
    trace = generate_trace(4, 1e9,
                           TraceConfig(seed=9, mean_turns=3.0,
                                       tool_mean_s=8.0),
                           arrival_process="saturation")

    def run(name):
        nodes = [SimNode(node_id=0, role="prefill", cost=cost),
                 SimNode(node_id=1, role="decode", cost=cost, n_slots=1),
                 SimNode(node_id=2, role="decode", cost=cost, n_slots=4)]
        sched = make_scheduler(name)
        # pin every binding to the tiny decoder 1 so bindings reliably park
        # there; only the reoffer policy differs between the two runs
        sched.bind_decoder = lambda conv, view: Placement(1,
                                                          kv_transfer=True)
        sim = ClusterSimulator(sched, nodes)
        recs = sim.serve(trace)
        assert len(recs) == 4
        return sim

    fifo = run("conserve")
    reb = run("conserve_rebalance")
    # FIFO serializes everything through decoder 1's single slot
    assert all(s.node_id == 1 for s in fifo.sessions.values())
    # the rebalancer moved parked bindings to the idle decoder 2
    assert any(s.node_id == 2 for s in reb.sessions.values())
    assert sum(reb.queue_waits().values()) < sum(fifo.queue_waits().values())


def test_reoffer_move_to_never_fitting_node_is_vetoed(qwen):
    """The reoffer hook sees only (cid, node, view) — it cannot check
    need_tokens. When a policy names a node the parked work could NEVER
    fit (heterogeneous capacities), the MECHANISM vetoes the move and the
    work keeps waiting on its origin instead of the loud never-fits check
    killing the serve."""
    cfg, model, params = qwen

    class MoveToTiny(ConServeScheduler):
        name = "move_to_tiny"

        def bind_decoder(self, conv, view):
            return Placement(1, kv_transfer=True)

        def reoffer_admission(self, cid, node_id, view):
            return Placement(2)  # naive: never checks fit

    reps = [ReplicaEngine(cfg, params, n_slots=4, max_ctx=512,
                          replica_id=0, role="prefill"),
            ReplicaEngine(cfg, params, n_slots=1, max_ctx=512, replica_id=1),
            ReplicaEngine(cfg, params, n_slots=4, max_ctx=64, replica_id=2)]
    srv = EngineServer(MoveToTiny(), reps, strict_accounting=True)
    trace = [Conversation(cid=i, arrival_s=i * 1e-9, turns=[
        Turn(append_tokens=100, output_tokens=4, tool_time_s=0.0)])
        for i in range(3)]
    recs = srv.serve(trace)  # must NOT raise "can never fit on replica 2"
    assert len(recs) == 3
    assert srv.n_deferred_admissions > 0  # bindings really did park
    # the vetoed moves left every conversation on the only decoder that
    # could ever hold its 100-token context
    assert all(s.node_id == 1 for s in srv.sessions.values())


def test_sim_lane_observables_track_decode_occupancy():
    """The simulator maintains the same lane observables as the engine: at
    its fidelity every emitting lane-step is live (masked == 0) and
    slot_busy_fraction reflects batch over declared slots."""
    model = ServedModelProfile()
    nodes = [SimNode(node_id=0, role="prefill",
                     cost=NodeCostModel(A40, model)),
             SimNode(node_id=1, role="decode",
                     cost=NodeCostModel(A40, model), n_slots=4)]
    sim = ClusterSimulator(make_scheduler("conserve"), nodes)
    recs = sim.serve(generate_trace(6, 1e9, TraceConfig(seed=5),
                                    arrival_process="saturation"))
    assert len(recs) == 6
    st = nodes[1].state
    assert st.decode_scan_steps > 0
    assert st.masked_forward_fraction == 0.0
    assert 0.0 < st.slot_busy_fraction <= 1.0


def test_never_fits_refill_error_names_conversation_node_headroom(qwen):
    """A refill candidate that can NEVER fit (context > every slot's
    max_ctx / the node's capacity) raises at offer time, naming the
    conversation, the node, and the slot headroom — mirroring the
    SlotKVCache.acquire() message style, on BOTH backends."""
    cfg, model, params = qwen
    rep = ReplicaEngine(cfg, params, n_slots=2, max_ctx=64, replica_id=3,
                        role="mixed")
    srv = EngineServer(make_scheduler("conserve"), [rep])
    conv = Conversation(cid=77, arrival_s=0.0, turns=[
        Turn(append_tokens=200, output_tokens=4, tool_time_s=0.0)])
    with pytest.raises(RuntimeError,
                       match=r"conversation 77 can never fit on replica 3: "
                             r"needs 200 KV tokens.*max_ctx=64.*0/2 slots"):
        srv.serve([conv])

    cost = NodeCostModel(A40, ServedModelProfile())
    nodes = [SimNode(node_id=0, role="prefill", cost=cost),
             SimNode(node_id=1, role="decode", cost=cost, n_slots=2)]
    sim = ClusterSimulator(make_scheduler("conserve"), nodes)
    cap = nodes[1].state.kv_capacity_tokens
    conv = Conversation(cid=5, arrival_s=0.0, turns=[
        Turn(append_tokens=cap + 1, output_tokens=4, tool_time_s=0.0)])
    with pytest.raises(RuntimeError,
                       match=r"conversation 5 can never fit on node 1: "
                             rf"needs {cap + 1} KV tokens.*0/2 slots"):
        sim.serve([conv])


# --------------------------------------------------------------------------- #
# scheduler re-offer hook
# --------------------------------------------------------------------------- #
def test_reoffer_hook_moves_parked_work():
    class Redirecting(ConServeScheduler):
        """Binds everything to decoder 1 (tiny) so bindings reliably park,
        then uses the re-offer decision point to move parked work to the
        spare decoder 2 — the hook schedulers like ConServe leave at its
        FIFO default."""
        name = "redirecting"

        def __init__(self):
            super().__init__()
            self.redirected = []

        def bind_decoder(self, conv, view):
            return Placement(1, kv_transfer=True)

        def reoffer_admission(self, cid, node_id, view):
            others = [n.node_id for n in view.nodes("decode")
                      if n.node_id != node_id and n.free_slots > 0]
            if others:
                self.redirected.append((cid, node_id, others[0]))
                return Placement(others[0])
            return None

    model = ServedModelProfile()
    cost = NodeCostModel(A40, model)
    nodes = [SimNode(node_id=0, role="prefill", cost=cost),
             SimNode(node_id=1, role="decode", cost=cost, n_slots=1),
             SimNode(node_id=2, role="decode", cost=cost, n_slots=4)]
    sched = Redirecting()
    sim = ClusterSimulator(sched, nodes)
    trace = generate_trace(3, 1e9,
                           TraceConfig(seed=9, mean_turns=3.0,
                                       tool_mean_s=8.0),
                           arrival_process="saturation")
    recs = sim.serve(trace)
    assert len(recs) == 3
    assert sched.redirected  # parked work WAS re-offered through the hook
    for cid, src, dst in sched.redirected:
        assert src == 1 and dst == 2
        assert sim.sessions[cid].node_id == dst


# --------------------------------------------------------------------------- #
# observables mirror ground truth (engine)
# --------------------------------------------------------------------------- #
def test_engine_accounting_matches_kv_ground_truth(qwen):
    """NodeState.active_kv_tokens must equal the sum of live kv.lengths on
    every replica at every conversation end — asserted continuously via
    strict_accounting across a multi-turn, multi-replica serve."""
    cfg, model, params = qwen
    reps = [ReplicaEngine(cfg, params, n_slots=8, max_ctx=512,
                          replica_id=0, role="prefill"),
            ReplicaEngine(cfg, params, n_slots=8, max_ctx=512, replica_id=1),
            ReplicaEngine(cfg, params, n_slots=8, max_ctx=512, replica_id=2)]
    srv = EngineServer(make_scheduler("conserve"), reps,
                       strict_accounting=True)
    tc = TraceConfig(seed=4, first_input_median=40, first_input_sigma=0.3,
                     first_input_max=90, append_median=12, append_sigma=0.4,
                     append_max=30, output_median=6, output_sigma=0.5,
                     output_max=12, mean_turns=2.5, max_turns=4,
                     tool_mean_s=0.01)
    recs = srv.serve(generate_trace(6, 5.0, cfg=tc))
    assert len(recs) == 6
    srv.check_accounting()
    for st in srv.states.values():
        assert st.active_kv_tokens == 0 and st.used_slots == 0


def test_engine_remote_turn_accounting_full_disagg(qwen):
    """Remote append-prefill turns (full_disagg routes every turn 2+ through
    the prefiller) must keep the mirror exact on BOTH nodes: the remote
    node's append is credited before its temporary slot releases."""
    cfg, model, params = qwen
    reps = [ReplicaEngine(cfg, params, n_slots=8, max_ctx=512,
                          replica_id=0, role="prefill"),
            ReplicaEngine(cfg, params, n_slots=8, max_ctx=512, replica_id=1)]
    srv = EngineServer(make_scheduler("full_disagg"), reps,
                       strict_accounting=True)
    tc = TraceConfig(seed=6, first_input_median=40, first_input_sigma=0.3,
                     first_input_max=90, append_median=12, append_sigma=0.4,
                     append_max=30, output_median=6, output_sigma=0.5,
                     output_max=12, mean_turns=3.0, max_turns=4,
                     tool_mean_s=0.01)
    recs = srv.serve(generate_trace(5, 5.0, cfg=tc))
    assert len(recs) == 5
    assert any(r.n_remote_turns > 0 for r in recs)
    srv.check_accounting()
    for st in srv.states.values():
        assert st.active_kv_tokens == 0 and st.used_slots == 0


# --------------------------------------------------------------------------- #
# selectable decode attention kernel (attention_impl)
# --------------------------------------------------------------------------- #
def test_attention_impl_pallas_matches_xla_decode(qwen):
    """The flash-decode kernel behind attention_impl="pallas" must be
    token-exact against the default jnp decode path, through both the fused
    scan and the single-dispatch reference path."""
    cfg, model, params = qwen

    def roll(impl):
        eng = ReplicaEngine(cfg, params, n_slots=2, max_ctx=256,
                            attention_impl=impl)
        assert eng.attention_impl == impl
        s = eng.kv.acquire()
        t, _ = eng.prefill_conversation(s, np.arange(7, 40, dtype=np.int32))
        toks = [int(t)]
        nt = np.zeros(2, np.int32)
        em = np.zeros(2, bool)
        em[s] = True
        nt[s] = toks[-1]
        seq, _ = eng.decode_steps(nt, em, 3)   # fused scan path
        toks += [int(x) for x in seq[:, s]]
        nt[s] = toks[-1]
        samp, _ = eng.decode_step_all_reference(nt, em)  # per-token path
        toks.append(int(samp[s]))
        return toks

    assert roll("xla") == roll("pallas")
