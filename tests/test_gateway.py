"""Live gateway e2e: the async streaming front end must be a pure OBSERVER —
live staged submission through `ServeGateway` streams byte-identically to an
offline `Runtime.serve()` replay of the same trace on both backends (engine:
token ids, incl. under an injected replica failure; sim: per-turn counts),
the circuit breaker refuses without crashing, and late submission after
run() is a loud error naming the runtime state on both backends."""
import asyncio

import jax
import pytest

from repro.configs import get_reduced
from repro.core import make_scheduler
from repro.core.events import (EV_ADMISSION_ADMIT, EV_ADMISSION_PARK,
                               EV_SESSION)
from repro.engine import EngineServer, ReplicaEngine
from repro.models import build_model
from repro.serve import (GatewayClient, GatewayOverloaded, ServeGateway,
                         serve_scenario_live)
from repro.traces import make_scenario


@pytest.fixture(scope="module")
def qwen():
    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(qwen, n_slots=8, roles=("prefill", "decode", "decode")):
    cfg, _, params = qwen
    reps = [ReplicaEngine(cfg, params, n_slots=n_slots, max_ctx=1024,
                          replica_id=i, role=r)
            for i, r in enumerate(roles)]
    return EngineServer(make_scheduler("conserve"), reps,
                        record_tokens=True, strict_accounting=True)


def _trace(seed=2, n=5):
    return make_scenario("shared_preamble_fleet", n, seed=seed,
                         scale="engine")


# --------------------------------------------------------------------------- #
# engine: live stream byte-identity vs offline replay
# --------------------------------------------------------------------------- #
def test_engine_gateway_streams_byte_identical(qwen):
    off = _engine(qwen)
    off.serve(_trace())
    offline = {k: list(v) for k, v in off.sampled_tokens.items()}

    live = _engine(qwen)
    recs, gw, client = serve_scenario_live(live, _trace())
    assert len(recs) == 5
    # the gateway's accumulation IS the engine's own stream state...
    assert gw.streams == live.sampled_tokens
    # ...and live staged arrival changes nothing about token content
    assert gw.streams == offline
    assert client.collected == offline
    live.check_accounting()
    # health reads the same NodeState observables schedulers see
    h = gw.health()
    assert h["runtime_state"] == "closed" and h["n_done"] == 5
    # a failure-free run sees zero lifecycle churn
    assert h["n_node_joins"] == 0 and h["n_node_quarantines"] == 0
    for st in h["nodes"].values():
        assert {"kv_headroom_tokens", "queued_conversations",
                "masked_forward_fraction", "lifecycle"} <= set(st)
        assert st["lifecycle"] == "ACTIVE"


def test_engine_gateway_identical_under_replica_failure(qwen):
    off = _engine(qwen)
    off.serve(_trace())
    offline = {k: list(v) for k, v in off.sampled_tokens.items()}

    live = _engine(qwen).fail_replica(1, at_s=0.4)
    recs, gw, client = serve_scenario_live(live, _trace())
    assert len(recs) == 5
    assert any(r.recovered for r in recs), "failure missed every conv"
    # the recovery event rewound the interrupted turn's accumulation and
    # deterministic replay re-streamed it — byte-identical end state
    assert gw.streams == offline
    assert client.collected == offline
    assert sum(client.rewinds.values()) >= 1
    assert gw.events_seen["node_failure"] == 1
    assert gw.events_seen["recovery"] >= 1
    live.check_accounting()


# --------------------------------------------------------------------------- #
# simulator: live turn-level stream identity vs offline replay
# --------------------------------------------------------------------------- #
def test_sim_gateway_turn_streams_identical():
    from repro.cluster import paper_deployment

    convs = make_scenario("pareto_burst", 10, seed=5, scale="paper")
    off = paper_deployment("conserve").serve(convs)
    off_counts = {(r.cid, i): t.n_output_tokens
                  for r in off for i, t in enumerate(r.turns)}

    live_convs = make_scenario("pareto_burst", 10, seed=5, scale="paper")
    recs, gw, _ = serve_scenario_live(paper_deployment("conserve"),
                                      live_convs)
    assert len(recs) == 10
    assert {k: sum(v) for k, v in gw.streams.items()} == off_counts
    # first streamed token observed for every conversation, after arrival
    for c in live_convs:
        assert gw.first_token_t[c.cid] >= c.arrival_s


def test_sim_gateway_identical_under_node_failure():
    from repro.cluster import paper_deployment

    convs = make_scenario("pareto_burst", 10, seed=5, scale="paper")
    off = paper_deployment("conserve").serve(convs)
    off_counts = {(r.cid, i): t.n_output_tokens
                  for r in off for i, t in enumerate(r.turns)}

    sim = paper_deployment("conserve")
    sim.inject_failure(node_id=1, at_s=15.0)
    recs, gw, _ = serve_scenario_live(
        sim, make_scenario("pareto_burst", 10, seed=5, scale="paper"))
    assert len(recs) == 10
    assert {k: sum(v) for k, v in gw.streams.items()} == off_counts
    assert gw.events_seen["node_failure"] == 1


# --------------------------------------------------------------------------- #
# circuit breaker: overload refuses new work, never crashes admitted work
# --------------------------------------------------------------------------- #
def test_circuit_breaker_sheds_without_crashing(qwen):
    srv = _engine(qwen, n_slots=1, roles=("mixed", "mixed"))
    burst = make_scenario("pareto_burst", 8, seed=9, scale="engine")
    for c in burst:
        c.arrival_s = 0.0
    extra = make_scenario("pareto_burst", 4, seed=11, scale="engine",
                          cid_offset=100)

    async def run():
        gw = ServeGateway(srv, shed_watermark=0, max_events_per_tick=8)
        gw.start()
        gw.submit(burst)
        shed = False
        for _ in range(400):
            await asyncio.sleep(0)
            try:
                gw.submit([extra[0]])
                extra.pop(0)
            except GatewayOverloaded as e:
                assert "watermark" in str(e) and "depths" in str(e)
                # observed backoff hints ride on the error (read from
                # NodeState at shed time, never predicted)
                assert e.min_queue_depth is not None \
                    and e.min_queue_depth >= 1
                assert e.retry_after_s is not None and e.retry_after_s >= 0.0
                shed = True
                break
            if not extra:
                break
        recs = await gw.drain()
        return gw, recs, shed

    gw, recs, shed = asyncio.run(run())
    assert shed and gw.n_shed >= 1
    # every ADMITTED conversation still completed — refusal, not a crash
    assert len(recs) == gw.n_submitted
    srv.check_accounting()


# --------------------------------------------------------------------------- #
# lifecycle: late submission is a loud error on BOTH backends
# --------------------------------------------------------------------------- #
def test_late_submit_raises_loudly_engine(qwen):
    srv = _engine(qwen)
    srv.serve(_trace(n=2))
    with pytest.raises(RuntimeError, match="closed") as ei:
        srv.submit(_trace(seed=3, n=1))
    assert "EngineServer" in str(ei.value)
    assert "run_pending" in str(ei.value)  # names the live alternative


def test_late_submit_raises_loudly_sim():
    from repro.cluster import paper_deployment

    sim = paper_deployment("conserve")
    sim.serve(make_scenario("pareto_burst", 3, seed=1, scale="paper"))
    with pytest.raises(RuntimeError, match="closed") as ei:
        sim.submit(make_scenario("pareto_burst", 1, seed=2, scale="paper"))
    assert "ClusterSimulator" in str(ei.value)


def test_gateway_rejects_submit_after_drain(qwen):
    srv = _engine(qwen)

    async def run():
        gw = ServeGateway(srv)
        gw.start()
        gw.submit(_trace(n=2))
        await gw.drain()
        with pytest.raises(RuntimeError, match="draining"):
            gw.submit(_trace(seed=3, n=1))

    asyncio.run(run())


# --------------------------------------------------------------------------- #
# event bus: admission park/admit and session transitions are observable
# --------------------------------------------------------------------------- #
def test_event_bus_observes_admission_and_sessions(qwen):
    srv = _engine(qwen, n_slots=1, roles=("mixed", "mixed"))
    seen = {"park": 0, "admit": 0, "session": []}
    srv.bus.subscribe(lambda ev: seen.__setitem__("park", seen["park"] + 1),
                      kinds=[EV_ADMISSION_PARK])
    srv.bus.subscribe(lambda ev: seen.__setitem__("admit", seen["admit"] + 1),
                      kinds=[EV_ADMISSION_ADMIT])
    srv.bus.subscribe(
        lambda ev: seen["session"].append((ev.cid, ev.data["prev"],
                                           ev.data["state"])),
        kinds=[EV_SESSION])
    burst = _trace(n=5)
    for c in burst:
        c.arrival_s = 0.0
    recs = srv.serve(burst)
    assert len(recs) == 5
    # 5 convs on 2 single-slot nodes: some MUST park, all eventually admit
    assert seen["park"] >= 1
    assert seen["admit"] >= seen["park"]
    dones = [s for s in seen["session"] if s[2] == "DONE"]
    assert len(dones) == 5
    # bus state mirrors the session machine, not a second bookkeeping path
    for cid, sess in srv.sessions.items():
        assert sess.done


def test_event_bus_rejects_unknown_kind(qwen):
    srv = _engine(qwen)
    with pytest.raises(ValueError, match="unknown event kind"):
        srv.bus.subscribe(lambda ev: None, kinds=["tokenz"])
