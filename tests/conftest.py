import os
import sys
from pathlib import Path

# NOTE: deliberately NO XLA_FLAGS here — tests run on the single real CPU
# device; only launch/dryrun.py forces 512 host devices (see system design).
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
