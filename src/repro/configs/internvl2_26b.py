"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT frontend is a STUB (input_specs supplies patch
embeddings), InternLM2 backbone. [arXiv:2404.16821; hf]"""
from repro.models.config import ATTN_GLOBAL, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92_553,
    activation="silu",
    norm="rmsnorm",
    block_pattern=(ATTN_GLOBAL,),
    rope_theta=1_000_000.0,
    frontend="vision",
    frontend_len=256,  # one 448px tile after pixel-unshuffle
)
