"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536 —
Finch, data-dependent decay. O(1) decode state. [arXiv:2404.05892; hf]"""
from repro.models.config import RWKV6, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    head_dim=64,
    d_ff=8960,
    vocab_size=65_536,
    norm="layernorm",
    block_pattern=(RWKV6,),
    rwkv_head_size=64,
    max_seq=1_048_576,
)
