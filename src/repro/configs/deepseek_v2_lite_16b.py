"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H (GQA kv=16) per-expert
d_ff=1408, vocab=102400, MoE 64 routed top-6 + 2 shared — MLA kv_lora=512.
[arXiv:2405.04434; hf]

Fidelity note: the real model keeps layer 0 dense; we run MoE in all 27
layers to keep the layer-scan homogeneous (recorded in DESIGN.md)."""
from repro.models.config import ATTN_MLA, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,              # per-expert hidden dim
    vocab_size=102_400,
    activation="silu",
    norm="rmsnorm",
    block_pattern=(ATTN_MLA,),
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    d_expert=1408,
    kv_lora_rank=512,
    q_lora_rank=0,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    rope_theta=10_000.0,
)
