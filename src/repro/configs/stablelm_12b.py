"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352. [hf:stabilityai/stablelm-2-1_6b family; hf]"""
from repro.models.config import ATTN_GLOBAL, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100_352,
    activation="silu",
    norm="layernorm",
    block_pattern=(ATTN_GLOBAL,),
    qk_norm=True,
    rope_theta=10_000.0,
)
