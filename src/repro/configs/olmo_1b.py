"""olmo-1b [dense]: 16L d_model=2048 16H (GQA kv=16 = MHA) d_ff=8192
vocab=50304 — non-parametric LayerNorm. [arXiv:2402.00838; hf]"""
from repro.models.config import ATTN_GLOBAL, ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50_304,
    activation="silu",
    norm="nonparametric_ln",
    block_pattern=(ATTN_GLOBAL,),
    rope_theta=10_000.0,
    tie_embeddings=True,
)
