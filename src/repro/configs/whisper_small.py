"""whisper-small [audio]: enc-dec, 12L decoder d_model=768 12H (kv=12)
d_ff=3072 vocab=51865 — conv frontend is a STUB (input_specs supplies frame
embeddings for the 12L encoder). [arXiv:2212.04356; unverified]"""
from repro.models.config import ATTN_GLOBAL, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51_865,
    activation="gelu",
    gated_mlp=False,
    norm="layernorm",
    block_pattern=(ATTN_GLOBAL,),
    is_encoder_decoder=True,
    n_encoder_layers=12,
    encoder_seq=1500,
    frontend="audio",
    frontend_len=1500,
    max_seq=40_960,
)
