"""qwen3-0.6b — the paper's served model (ConServe evaluation backbone).
28L d_model=1024 16H (GQA kv=8) head_dim=128 d_ff=3072 vocab=151936."""
from repro.models.config import ATTN_GLOBAL, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151_936,
    activation="silu",
    norm="rmsnorm",
    block_pattern=(ATTN_GLOBAL,),
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
