"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global sliding-window attention, 128k context.
[hf:google/gemma-3-1b-pt family; unverified]"""
from repro.models.config import ATTN_GLOBAL, ATTN_LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=240,
    d_ff=15360,
    vocab_size=262_144,
    activation="gelu",
    norm="rmsnorm",
    block_pattern=(ATTN_LOCAL,) * 5 + (ATTN_GLOBAL,),
    window=1024,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    qk_norm=True,
    max_seq=131_072,
)
