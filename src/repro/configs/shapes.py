"""Assigned input-shape sets. LM-family shapes are (seq_len, global_batch);
decode_* / long_* lower `serve_step` (one new token against a KV cache of
seq_len), not `train_step`."""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def get_shape(name: str) -> ShapeSpec:
    return SHAPES[name]
