"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (GQA kv=1 = MQA)
d_ff=12288 vocab=256000 — RG-LRU + local attention, pattern 2 recurrent :
1 attention (Griffin). 38 = 12x3 + 2 -> two trailing RG-LRU layers.
[arXiv:2402.19427; unverified]"""
from repro.models.config import ATTN_LOCAL, RGLRU, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    activation="gelu",
    norm="rmsnorm",
    block_pattern=(RGLRU, RGLRU, ATTN_LOCAL),
    window=2048,
    lru_width=4096,
    conv1d_width=4,
    rope_theta=10_000.0,
    max_seq=1_048_576,
)
