"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192,
MoE 16 experts top-1 + 1 shared expert, vocab=202048 — early-fusion
multimodal in the original; assigned as text backbone.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.models.config import ATTN_GLOBAL, ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    activation="silu",
    norm="rmsnorm",
    block_pattern=(ATTN_GLOBAL,),
    n_experts=16,
    top_k=1,
    n_shared_experts=1,
    d_expert=8192,
    rope_theta=500_000.0,
)
