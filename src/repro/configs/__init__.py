"""Architecture config registry: one module per assigned architecture plus
the paper's own served model (qwen3-0.6b). `get_config(arch)` returns the
full published config; `get_reduced(arch)` the family-preserving smoke-test
reduction."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig, reduced_config

_MODULES = {
    "gemma3-12b": "gemma3_12b",
    "stablelm-12b": "stablelm_12b",
    "nemotron-4-15b": "nemotron4_15b",
    "olmo-1b": "olmo_1b",
    "internvl2-26b": "internvl2_26b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "llama4-scout-17b-a16e": "llama4_scout_17b",
    "rwkv6-3b": "rwkv6_3b",
    "whisper-small": "whisper_small",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "qwen3-0.6b": "qwen3_0p6b",
}

ASSIGNED: List[str] = [k for k in _MODULES if k != "qwen3-0.6b"]
ALL_ARCHS: List[str] = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return reduced_config(get_config(arch))


from .shapes import SHAPES, ShapeSpec, get_shape  # noqa: E402

__all__ = ["get_config", "get_reduced", "ASSIGNED", "ALL_ARCHS", "SHAPES",
           "ShapeSpec", "get_shape"]
