import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Refresh the depth-probe measurements inside existing dry-run artifacts
(after probe methodology changes) WITHOUT recompiling the main cells.

  python -m repro.launch.reprobe [--mesh 16x16] [--variant base]
"""
import argparse
import json
from pathlib import Path

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--only-arch", default=None)
    args = ap.parse_args()

    import jax
    from repro.launch.dryrun import parse_collectives
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_cell, probe_config
    from repro.configs import get_config

    mesh = make_production_mesh(multi_pod=(args.mesh == "2x16x16"))
    for f in sorted(ARTIFACT_DIR.glob(f"*__{args.mesh}__{args.variant}.json")):
        rec = json.loads(f.read_text())
        if not rec.get("supported"):
            continue
        arch, shape = rec["arch"], rec["shape"]
        if args.only_arch and arch != args.only_arch:
            continue
        cfg_full = get_config(arch)
        _, n_groups, _ = cfg_full.pattern_groups()
        probes = {"n_groups": n_groups,
                  "pattern_len": len(cfg_full.block_pattern),
                  "method": "unrolled+block_full"}
        if n_groups > 1:
            for k in (1, 2):
                pcfg = probe_config(arch, k)
                pfn, pargs = build_cell(arch, shape, mesh, cfg=pcfg)
                with mesh:
                    pc = jax.jit(pfn).lower(*pargs).compile()
                    cost = pc.cost_analysis()
                coll, _ = parse_collectives(pc.as_text())
                probes[f"g{k}"] = {
                    "flops": float((cost or {}).get("flops", -1)),
                    "bytes_accessed": float((cost or {}).get(
                        "bytes accessed", -1)),
                    "collective_total": sum(coll.values()),
                }
        rec["probes"] = probes
        f.write_text(json.dumps(rec, indent=2))
        g = probes.get("g2", {}).get("flops", 0) - probes.get(
            "g1", {}).get("flops", 0)
        print(f"[reprobe] {arch} {shape}: per-group flops {g:.3e}", flush=True)


if __name__ == "__main__":
    main()
