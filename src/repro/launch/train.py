"""Training launcher: builds the sharded train step for an (arch, mesh) and
either dry-runs it (lower+compile, default on this CPU container) or executes
real steps when the mesh is backed by physical devices.

  python -m repro.launch.train --arch olmo-1b [--multi-pod] [--execute]
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--execute", action="store_true",
                    help="run real steps (requires a real device mesh); "
                         "default is lower+compile only")
    args = ap.parse_args()

    import os
    if not args.execute:
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=512")
    import jax

    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_train_program

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    step_fn, (params, opt, batch) = build_train_program(
        args.arch, mesh, grad_accum=args.grad_accum,
        compress_grads=args.compress_grads)
    with mesh:
        compiled = jax.jit(step_fn).lower(params, opt, batch).compile()
        print(compiled.memory_analysis())
        print("compiled OK for", args.arch, "on", mesh.shape)
        if args.execute:
            import numpy as np
            from repro.configs import get_config
            from repro.models import build_model
            from repro.train import DataConfig, SyntheticLM, adamw_init
            cfg = get_config(args.arch)
            model = build_model(cfg)
            p = model.init(jax.random.PRNGKey(0))
            o = adamw_init(p)
            data = SyntheticLM(DataConfig(cfg.vocab_size, 4096, 256))
            for i in range(args.steps):
                b = data.batch(i)
                p, o, m = compiled(p, o, b)
                print(f"step {i}: loss={float(m['loss']):.4f}")


if __name__ == "__main__":
    main()
