"""ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no device
allocation) for every model input of every (architecture × shape) cell, plus
the program builders the dry-run lowers.

Programs per shape kind:
  train_*    -> train_step(params, opt_state, batch)
  prefill_*  -> prefill_step(params, tokens[, frontend_embeds])
  decode_* / long_* -> serve_step(params, token, caches, position)
                       (one new token against a KV cache of seq_len)
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_shape
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.models.sharding import (cache_pspecs, data_pspec, mesh_axes,
                                   param_pspecs)
from repro.train.optimizer import AdamWConfig, adamw_state_skeleton
from repro.train.train_step import make_train_step


def cell_supported(arch: str, shape_name: str) -> Tuple[bool, str]:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("skipped: pure full-attention architecture; long_500k "
                       "requires sub-quadratic attention (DESIGN.md §4)")
    if shape.seq_len > cfg.max_seq:
        return False, f"skipped: seq_len {shape.seq_len} > max_seq {cfg.max_seq}"
    return True, "ok"


def _named(mesh: Mesh, sds_tree, pspec_tree):
    return jax.tree_util.tree_map(
        lambda l, s: jax.ShapeDtypeStruct(
            l.shape, l.dtype, sharding=NamedSharding(mesh, s)),
        sds_tree, pspec_tree)


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch: int, seq: int,
                with_labels: bool) -> Dict[str, Any]:
    dp, _ = mesh_axes(mesh)
    tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32,
                               sharding=NamedSharding(mesh, data_pspec(dp, 2)))
    out = {"tokens": tok}
    if with_labels:
        out["labels"] = tok
    if cfg.frontend != "none":
        fl = cfg.frontend_len or cfg.encoder_seq
        out["frontend_embeds"] = jax.ShapeDtypeStruct(
            (batch, fl, cfg.d_model), cfg.jnp_dtype,
            sharding=NamedSharding(mesh, data_pspec(dp, 3)))
    return out


def sharded_params(cfg: ModelConfig, mesh: Mesh, model=None,
                   sharding_mode: str = "tp"):
    model = model or build_model(cfg)
    sk = model.skeleton()
    return _named(mesh, sk, param_pspecs(cfg, sk, mode=sharding_mode))


def sharded_caches(cfg: ModelConfig, mesh: Mesh, batch: int, ctx: int,
                   model=None):
    model = model or build_model(cfg)
    ck = model.cache_skeleton(batch, ctx)
    dp, _ = mesh_axes(mesh)
    # batch=1 long-context cells: put every data axis on the KV length dim
    # (whole-mesh context parallelism) instead of a size-1 batch dim.
    if batch == 1:
        specs = jax.tree_util.tree_map_with_path(
            lambda p, l: _long_ctx_spec(p, l, dp), ck)
    else:
        specs = cache_pspecs(cfg, ck, dp)
    return _named(mesh, ck, specs)


def _long_ctx_spec(path, leaf, dp):
    names = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
    name = names[-1]
    grouped = leaf.ndim >= 4 and names[0] in ("groups", "self", "cross")
    lead = (None,) if grouped else ()
    rank = len(leaf.shape)
    if name in ("k", "v", "ckv", "krope"):
        ln_axis = (*dp, "model")
        tail = (None, ln_axis) + (None,) * (rank - len(lead) - 2)
        return P(*lead, *tail)
    return P(*lead, *((None,) * (rank - len(lead))))


# --------------------------------------------------------------------------- #
# Program builders
# --------------------------------------------------------------------------- #
def build_train_program(arch: str, mesh: Mesh, *, grad_accum: int = 1,
                        compress_grads: bool = False, remat: bool = True,
                        loss_chunk: int = 512, sharding_mode: str = "tp",
                        cfg=None):
    cfg = cfg or get_config(arch)
    model = build_model(cfg)
    shape = get_shape("train_4k")
    opt_cfg = AdamWConfig()
    step_fn = make_train_step(model, opt_cfg, remat=remat,
                              grad_accum=grad_accum,
                              compress_grads=compress_grads,
                              loss_chunk=loss_chunk)
    params = sharded_params(cfg, mesh, model, sharding_mode=sharding_mode)
    opt = adamw_state_skeleton(model.skeleton())
    opt_specs = {
        "mu": param_pspecs(cfg, model.skeleton(), mode=sharding_mode),
        "nu": param_pspecs(cfg, model.skeleton(), mode=sharding_mode),
        "step": P(),
    }
    opt = _named(mesh, opt, opt_specs)
    batch = batch_specs(cfg, mesh, shape.global_batch, shape.seq_len, True)
    return step_fn, (params, opt, batch)


def build_prefill_program(arch: str, mesh: Mesh, shape_name: str = "prefill_32k",
                          cfg=None):
    cfg = cfg or get_config(arch)
    model = build_model(cfg)
    shape = get_shape(shape_name)

    if cfg.frontend != "none":
        def prefill_step(params, tokens, frontend_embeds):
            return model.prefill(params, tokens,
                                 frontend_embeds=frontend_embeds)
        batch = batch_specs(cfg, mesh, shape.global_batch, shape.seq_len, False)
        args = (sharded_params(cfg, mesh, model), batch["tokens"],
                batch["frontend_embeds"])
    else:
        def prefill_step(params, tokens):
            return model.prefill(params, tokens)
        batch = batch_specs(cfg, mesh, shape.global_batch, shape.seq_len, False)
        args = (sharded_params(cfg, mesh, model), batch["tokens"])
    return prefill_step, args


def build_decode_program(arch: str, mesh: Mesh, shape_name: str, cfg=None):
    cfg = cfg or get_config(arch)
    model = build_model(cfg)
    shape = get_shape(shape_name)
    dp, _ = mesh_axes(mesh)
    B, ctx = shape.global_batch, shape.seq_len

    def serve_step(params, token, caches, position):
        return model.decode_step(params, token, caches, position)

    tok_spec = P(dp if len(dp) > 1 else dp[0]) if B > 1 else P()
    token = jax.ShapeDtypeStruct((B,), jnp.int32,
                                 sharding=NamedSharding(mesh, tok_spec))
    position = jax.ShapeDtypeStruct((), jnp.int32,
                                    sharding=NamedSharding(mesh, P()))
    args = (sharded_params(cfg, mesh, model), token,
            sharded_caches(cfg, mesh, B, ctx, model), position)
    return serve_step, args


def build_cell(arch: str, shape_name: str, mesh: Mesh, cfg=None, **kw):
    kind = get_shape(shape_name).kind
    if kind == "train":
        return build_train_program(arch, mesh, cfg=cfg, **kw)
    if kind == "prefill":
        return build_prefill_program(arch, mesh, shape_name, cfg=cfg)
    return build_decode_program(arch, mesh, shape_name, cfg=cfg)


def probe_config(arch: str, k: int):
    """Depth probe: k pattern repetitions (k groups), used to measure
    per-layer-group FLOPs/bytes/collectives — XLA's cost analysis counts
    loop bodies once, so dryrun extrapolates X + (G-1)·(X_g2 - X_g1)."""
    cfg = get_config(arch)
    n = len(cfg.block_pattern) * k
    kw = {"n_layers": n, "unroll_layers": True, "attn_block_full": True,
          "flash_vjp": False}
    if cfg.is_encoder_decoder:
        kw["n_encoder_layers"] = n
    return cfg.scaled(**kw)
