import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell against the production mesh (16x16 single-pod / 2x16x16 multi-pod),
print memory_analysis() and cost_analysis(), parse the post-SPMD HLO for
collective traffic, and persist everything to JSON for §Dry-run / §Roofline.

The XLA_FLAGS line above MUST run before any jax import (jax locks device
count at first init); it is deliberately NOT set anywhere else — smoke tests
and benchmarks see the single real CPU device.

Usage:
  python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--jobs-file cells.txt]
"""
import argparse
import json
import re
import subprocess
import sys
import time
from pathlib import Path

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "pred": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _type_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str):
    """Sum result-buffer bytes of every collective op in post-SPMD HLO.
    (operand size == result size for all-reduce / permute / all-to-all; for
    all-gather this counts the full gathered buffer ~= wire traffic; see
    benchmarks/roofline.py for the accounting note)."""
    totals = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        for op in _COLLECTIVES:
            # match result op, not operands mentioned elsewhere
            if f" {op}(" in line or f" {op}-start(" in line:
                lhs = line.split(f" {op}", 1)[0]
                for dtype, dims in _SHAPE_RE.findall(lhs):
                    if dtype in _DTYPE_BYTES:
                        totals[op] += _type_bytes(dtype, dims)
                counts[op] += 1
                break
    return totals, counts


def run_cell(arch: str, shape_name: str, multi_pod: bool, variant: str = "base",
             out_dir: Path = ARTIFACT_DIR, cfg_overrides=None, **program_kw):
    import jax  # noqa: deferred so XLA_FLAGS applies
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_cell, cell_supported

    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}__{variant}"
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{tag}.json"

    ok, reason = cell_supported(arch, shape_name)
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "variant": variant, "supported": ok}
    if not ok:
        record["reason"] = reason
        out_path.write_text(json.dumps(record, indent=2))
        print(f"[dryrun] {tag}: {reason}")
        return record

    from repro.configs import get_config
    from repro.launch.specs import probe_config

    mesh = make_production_mesh(multi_pod=multi_pod)
    base_cfg = get_config(arch)
    cfg_used = base_cfg.scaled(**cfg_overrides) if cfg_overrides else None
    fn, args = build_cell(arch, shape_name, mesh, cfg=cfg_used, **program_kw)

    t0 = time.time()
    with mesh:
        jitted = jax.jit(fn)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        print(mem)    # proves it fits
        print({k: v for k, v in (cost or {}).items()
               if k in ("flops", "bytes accessed", "utilization operand 0")})

    hlo = compiled.as_text()
    coll, coll_counts = parse_collectives(hlo)

    # depth probes: XLA cost analysis counts while-loop bodies ONCE, so the
    # layer scan's per-group cost is measured directly from 1-group vs
    # 2-group reductions of the same cell and extrapolated in roofline.py.
    cfg_full = cfg_used or get_config(arch)
    _, n_groups, _ = cfg_full.pattern_groups()
    probes = {"n_groups": n_groups,
              "pattern_len": len(cfg_full.block_pattern)}
    if n_groups > 1:
        for k in (1, 2):
            pcfg = probe_config(arch, k)
            if cfg_overrides:
                pcfg = pcfg.scaled(**cfg_overrides)
            pfn, pargs = build_cell(arch, shape_name, mesh, cfg=pcfg,
                                    **program_kw)
            with mesh:
                pcompiled = jax.jit(pfn).lower(*pargs).compile()
                pcost = pcompiled.cost_analysis()
            pcoll, _ = parse_collectives(pcompiled.as_text())
            probes[f"g{k}"] = {
                "flops": float((pcost or {}).get("flops", -1)),
                "bytes_accessed": float((pcost or {}).get("bytes accessed", -1)),
                "collective_total": sum(pcoll.values()),
            }

    def _mem_attr(name):
        return getattr(mem, name, None) if mem is not None else None

    n_devices = 512 if multi_pod else 256
    record.update({
        "n_devices": n_devices,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops": float((cost or {}).get("flops", -1)),
        "bytes_accessed": float((cost or {}).get("bytes accessed", -1)),
        "memory": {
            "argument_bytes": _mem_attr("argument_size_in_bytes"),
            "output_bytes": _mem_attr("output_size_in_bytes"),
            "temp_bytes": _mem_attr("temp_size_in_bytes"),
            "generated_code_bytes": _mem_attr("generated_code_size_in_bytes"),
        },
        "collective_bytes": coll,
        "collective_counts": coll_counts,
        "collective_total": sum(coll.values()),
        "hlo_lines": len(hlo.splitlines()),
        "probes": probes,
    })
    out_path.write_text(json.dumps(record, indent=2))
    print(f"[dryrun] {tag}: flops={record['flops']:.3e} "
          f"coll={record['collective_total']:.3e}B "
          f"compile={t_compile:.1f}s")
    return record


def all_cells():
    from repro.configs import ASSIGNED, SHAPES
    return [(a, s) for a in ASSIGNED for s in SHAPES]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--in-process", action="store_true",
                    help="run --all cells in this process (default: one "
                         "subprocess per cell for isolation)")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--loss-chunk", type=int, default=0)
    ap.add_argument("--flash-vjp", action="store_true",
                    help="custom-VJP flash attention (train memory variant)")
    ap.add_argument("--kv-dtype", default="",
                    help="quantized KV cache dtype for decode cells (int8)")
    ap.add_argument("--rwkv-pad-heads", type=int, default=0)
    ap.add_argument("--remat-layer", action="store_true",
                    help="per-layer remat granularity (train memory variant)")
    ap.add_argument("--fsdp", action="store_true",
                    help="FSDP/ZeRO-3 param sharding on the model axis "
                         "(train variant; baseline is Megatron TP)")
    args = ap.parse_args()

    kw = {}
    if args.grad_accum != 1:
        kw["grad_accum"] = args.grad_accum
    if args.compress_grads:
        kw["compress_grads"] = True
    if args.no_remat:
        kw["remat"] = False
    if args.loss_chunk:
        kw["loss_chunk"] = args.loss_chunk
    if args.fsdp:
        kw["sharding_mode"] = "fsdp"
    overrides = {}
    if args.flash_vjp:
        overrides["flash_vjp"] = True
    if args.kv_dtype:
        overrides["kv_cache_dtype"] = args.kv_dtype
    if args.rwkv_pad_heads:
        overrides["rwkv_pad_heads_to"] = args.rwkv_pad_heads
    if args.remat_layer:
        overrides["remat_granularity"] = "layer"
    if overrides:
        kw["cfg_overrides"] = overrides

    if not args.all:
        assert args.arch and args.shape, "--arch and --shape required"
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        for mp in meshes:
            run_cell(args.arch, args.shape, mp, variant=args.variant, **kw)
        return

    cells = all_cells()
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    todo = []
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            tag = f"{arch}__{shape}__{mesh_name}__{args.variant}"
            if not args.force and (ARTIFACT_DIR / f"{tag}.json").exists():
                print(f"[dryrun] {tag}: cached, skip")
                continue
            todo.append((arch, shape, mp))

    if args.in_process:
        for arch, shape, mp in todo:
            run_cell(arch, shape, mp, variant=args.variant, **kw)
        return

    for arch, shape, mp in todo:
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--variant", args.variant]
        if mp:
            cmd.append("--multi-pod")
        if args.grad_accum != 1:
            cmd += ["--grad-accum", str(args.grad_accum)]
        if args.compress_grads:
            cmd.append("--compress-grads")
        if args.no_remat:
            cmd.append("--no-remat")
        if args.flash_vjp:
            cmd.append("--flash-vjp")
        if args.kv_dtype:
            cmd += ["--kv-dtype", args.kv_dtype]
        if args.rwkv_pad_heads:
            cmd += ["--rwkv-pad-heads", str(args.rwkv_pad_heads)]
        if args.loss_chunk:
            cmd += ["--loss-chunk", str(args.loss_chunk)]
        print("[dryrun] spawn:", " ".join(cmd), flush=True)
        r = subprocess.run(cmd)
        if r.returncode != 0:
            print(f"[dryrun] FAILED: {arch} {shape} multi_pod={mp}",
                  flush=True)


if __name__ == "__main__":
    main()
