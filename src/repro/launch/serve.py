"""Serving launcher: ConServe deployment driver.

Three modes:
  --engine  : real JAX replicas on local devices (CPU demo / single host)
  --sim     : the calibrated discrete-event cluster runtime
  default   : lower+compile the serve_step for the production mesh
              (prefill + decode programs for the chosen arch), proving the
              deployment's distribution config before touching hardware.

--engine and --sim drive their backend through the ONE shared
`repro.core.runtime.Runtime` contract (submit/run/results + admission
control), so the launcher — like the schedulers — cannot tell the two
scales apart.

  python -m repro.launch.serve --arch qwen3-0.6b [--multi-pod]
                               [--engine | --sim] [--slots N]
                               [--gateway] [--scenario NAME] [--seed S]

--scenario picks a named workload from the scenario library
(`repro.traces.SCENARIOS`); --gateway serves it LIVE through the async
streaming gateway (staged arrivals, per-token event bus) instead of the
offline submit+run batch path — same runtime, same records, plus live
streaming observables.
"""
import argparse


def _drive(runtime, trace, gateway: bool = False):
    """The whole serving contract, backend-agnostic. With `gateway`, the
    trace is injected live through `repro.serve` (staged arrivals driven by
    an asyncio loop) rather than submitted as one offline batch."""
    from repro.core.metrics import summarize
    if gateway:
        from repro.serve import serve_scenario_live
        recs, gw, _ = serve_scenario_live(runtime, trace)
        h = gw.health()
        print(f"  gateway: {h['n_submitted']} submitted, {h['n_done']} done, "
              f"{h['n_shed']} shed; events: {h['events_seen']}")
    else:
        recs = runtime.serve(trace)
    s = summarize(recs)
    for k in ("ttfet_gmean", "ttfet_p95", "last_tbt_gmean", "e2e_gmean",
              "kv_transfers_per_conv"):
        print(f"  {k}: {s[k]:.4f}")
    waits = [w for w in runtime.queue_waits().values() if w > 0]
    if waits:
        print(f"  admission waits: {len(waits)} conversations, "
              f"max {max(waits):.3f}s (backpressure, not a crash)")
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--engine", action="store_true")
    ap.add_argument("--sim", action="store_true")
    ap.add_argument("--scheduler", default="conserve",
                    choices=["conserve", "ampd", "collocated", "full_disagg"])
    ap.add_argument("--n-conversations", type=int, default=12)
    ap.add_argument("--slots", type=int, default=16,
                    help="engine: KV slots per replica (small values "
                         "exercise admission backpressure)")
    ap.add_argument("--no-rotation", action="store_true",
                    help="engine: disable continuous decode rotation "
                         "(adaptive chunk cuts + mid-tail slot refill) and "
                         "fall back to chunk-boundary-only admission — the "
                         "before/after comparison knob")
    ap.add_argument("--prefill-mode", default=None,
                    choices=["jit", "reference"],
                    help="engine: override the (append-)prefill path — "
                         "'jit' = AOT-compiled donated bucket programs "
                         "(replica default), 'reference' = the eager "
                         "per-op oracle — the before/after comparison knob")
    ap.add_argument("--gateway", action="store_true",
                    help="serve LIVE through the async streaming gateway "
                         "(staged arrivals + per-token event bus) instead "
                         "of the offline batch path")
    ap.add_argument("--scenario", default=None,
                    help="named workload from the scenario library "
                         "(pareto_burst, supervisor_worker, hitl_longpark, "
                         "shared_preamble_fleet); default: the classic "
                         "generate_trace workload")
    ap.add_argument("--seed", type=int, default=0,
                    help="scenario seed (byte-identical trace per seed)")
    args = ap.parse_args()

    if args.engine:
        import jax
        from repro.configs import get_reduced
        from repro.core import make_scheduler
        from repro.engine import EngineServer, ReplicaEngine
        from repro.models import build_model
        from repro.traces import TraceConfig, generate_trace

        cfg = get_reduced(args.arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        reps = [ReplicaEngine(cfg, params, n_slots=args.slots, max_ctx=1024,
                              replica_id=0, role="prefill")] + [
            ReplicaEngine(cfg, params, n_slots=args.slots, max_ctx=1024,
                          replica_id=i, role="decode") for i in (1, 2)]
        srv = EngineServer(make_scheduler(args.scheduler), reps,
                           rotation=not args.no_rotation,
                           prefill_mode=args.prefill_mode)
        if args.scenario:
            from repro.traces import make_scenario
            trace = make_scenario(args.scenario, args.n_conversations,
                                  seed=args.seed, scale="engine")
        else:
            tc = TraceConfig(first_input_median=150, first_input_max=500,
                             append_median=24, append_max=64,
                             output_median=10, output_max=32, mean_turns=3.0,
                             max_turns=6, tool_mean_s=0.05)
            trace = generate_trace(args.n_conversations, 2.0, cfg=tc)
        _drive(srv, trace, gateway=args.gateway)
        return

    if args.sim:
        from repro.cluster import paper_deployment
        from repro.traces import TraceConfig, generate_trace

        sim = paper_deployment(args.scheduler)
        if args.scenario:
            from repro.traces import make_scenario
            trace = make_scenario(args.scenario, args.n_conversations,
                                  seed=args.seed, scale="paper")
        else:
            trace = generate_trace(args.n_conversations, 1.634,
                                   TraceConfig(seed=17))
        _drive(sim, trace, gateway=args.gateway)
        return

    import os
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512")
    import jax
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_decode_program, build_prefill_program

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    with mesh:
        for name, (fn, a) in (
                ("prefill_32k", build_prefill_program(args.arch, mesh)),
                ("decode_32k", build_decode_program(args.arch, mesh,
                                                    "decode_32k"))):
            compiled = jax.jit(fn).lower(*a).compile()
            print(f"{name}: compiled OK on {mesh.shape}; "
                  f"{compiled.memory_analysis()}")


if __name__ == "__main__":
    main()
