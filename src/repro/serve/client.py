"""Client-side helpers for the serving gateway: per-conversation stream
collectors and a one-call live-serving harness used by the benchmarks, the
launcher and the e2e tests.

`serve_scenario_live` is the canonical live drive: conversations are staged
into the gateway in arrival order, a few at a time, with event batches
executing between stagings — genuine mid-flight submission, not a pre-loaded
batch — while per-conversation consumer tasks assemble each stream from the
`stream(cid)` generator (honoring failure rewinds). It returns the offline-
comparable records plus the assembled streams, so callers can assert the
byte-identity contract against `Runtime.serve()` replay.
"""
from __future__ import annotations

import asyncio
from typing import Dict, List, Optional, Tuple

from repro.core.conversation import Conversation

from .gateway import ServeGateway


class GatewayClient:
    """Consumes a gateway's per-conversation streams into assembled
    per-(cid, turn_idx) buffers. A ``rewind`` marker (failure recovery)
    discards the interrupted turn's partial buffer, mirroring the gateway's
    own accumulation — what remains after DONE is exactly what a live
    subscriber would have kept."""

    def __init__(self, gateway: ServeGateway):
        self.gateway = gateway
        # (cid, turn_idx) -> engine token ids, or per-turn counts on the sim
        self.collected: Dict[Tuple[int, int], List[int]] = {}
        self.rewinds: Dict[int, int] = {}

    async def collect(self, cid: int):
        """Drain one conversation's stream to completion."""
        async for item in self.gateway.stream(cid):
            if item[0] == "tokens":
                _, turn_idx, payload = item
                buf = self.collected.setdefault((cid, turn_idx), [])
                if isinstance(payload, list):
                    buf.extend(payload)
                else:
                    buf.append(int(payload))
            elif item[0] == "rewind":
                self.collected.pop((cid, item[1]), None)
                self.rewinds[cid] = self.rewinds.get(cid, 0) + 1


def serve_scenario_live(runtime, convs: List[Conversation], *,
                        shed_watermark: Optional[int] = None,
                        stagger: int = 2,
                        max_events_per_tick: int = 64,
                        ticks_between: int = 8):
    """Drive `runtime` live through a gateway: submit `convs` in arrival
    order, `stagger` at a time, executing up to `ticks_between` event
    batches between stagings so later submissions genuinely inject
    mid-flight. Returns ``(records, gateway, client)`` after a full drain.

    Overload shed (`GatewayOverloaded`) is NOT handled here — callers that
    want shedding behavior submit through the gateway themselves; this
    harness asserts the happy-path identity contract, so the watermark
    (when given) must be deep enough to admit the whole workload.
    """
    ordered = sorted(convs, key=lambda c: (c.arrival_s, c.cid))

    async def _run():
        gw = ServeGateway(runtime, shed_watermark=shed_watermark,
                          max_events_per_tick=max_events_per_tick)
        client = GatewayClient(gw)
        gw.start()
        consumers = [asyncio.ensure_future(client.collect(c.cid))
                     for c in ordered]
        for i in range(0, len(ordered), max(stagger, 1)):
            gw.submit(ordered[i:i + max(stagger, 1)])
            # let the driver execute a few batches before the next staging
            for _ in range(ticks_between):
                await asyncio.sleep(0)
        records = await gw.drain()
        await asyncio.gather(*consumers)
        return records, gw, client

    return asyncio.run(_run())
