from .gateway import GatewayOverloaded, ServeGateway
from .client import GatewayClient, serve_scenario_live

__all__ = ["GatewayOverloaded", "ServeGateway", "GatewayClient",
           "serve_scenario_live"]
