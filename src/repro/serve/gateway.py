"""Async serving gateway: live streaming admission over the shared `Runtime`
contract, working identically against both backends.

The gateway is a FRONT END, not a third runtime. Everything it reports is a
read of state the runtime already owns, delivered through the event bus
(`repro.core.events`) whose hooks fire from the runtime's own transition
points:

* per-token streams come from the decode rotation's finish events (the
  engine holds the authoritative per-(cid, turn) stream in `_TurnTask
  .stream`; the simulator emits at turn granularity — counts, no bytes);
* session progress comes from `ServeSession.transition`'s notify hook;
* health comes from the same `NodeState` observables schedulers read
  (`kv_headroom_tokens`, `queued_conversations`, `masked_forward_fraction`);
* backpressure comes from admission park/admit events plus the circuit
  breaker below, which REFUSES new work loudly (`GatewayOverloaded`) when
  every live node's admission queue exceeds a watermark — refusal is an
  observable signal, never a crash of in-flight work.

Because both backends run a logical clock behind `run_pending()`, the
gateway drives them incrementally from an asyncio loop: staged submissions
inject between event batches (the runtimes clamp past arrival timestamps to
now), and token callbacks fan out to per-conversation asyncio queues that
`stream(cid)` consumes. Determinism is preserved — the event heap orders
execution, the gateway only observes — so a live-submitted workload streams
byte-identically to an offline `Runtime.serve()` replay of the same trace,
including across an injected replica failure (the `recovery` event rewinds
the interrupted turn's accumulation; deterministic replay re-streams it
byte-for-byte).
"""
from __future__ import annotations

import asyncio
from collections import Counter
from typing import Any, Dict, List, Optional, Tuple

from repro.core.conversation import Conversation
from repro.core.events import (EV_NODE_JOIN, EV_NODE_QUARANTINE, EV_RECOVERY,
                               EV_SESSION, EV_TOKENS, ServeEvent)
from repro.core.runtime import DONE, Runtime


class GatewayOverloaded(RuntimeError):
    """Raised by `ServeGateway.submit` when the circuit breaker sheds new
    admissions: every live node's admission queue is deeper than the
    watermark. In-flight conversations are untouched — the caller is told
    to back off, which is the observable backpressure contract.

    Carries two observed quantities so callers can back off intelligently
    (both read straight from `NodeState` at shed time — no new bookkeeping):

    * `min_queue_depth` — the SHALLOWEST live node's admission-queue depth
      (by definition > watermark, or nothing would have shed);
    * `retry_after_s` — a drain-rate-derived hint: the shallowest node's
      queue depth × its observed mean resident context × its observed TBT
      EMA. 0.0 when that node has no decode observations yet (nothing
      observed means no basis for a hint — the contract forbids inventing
      a prediction).
    """

    def __init__(self, message: str, *,
                 min_queue_depth: Optional[int] = None,
                 retry_after_s: Optional[float] = None):
        super().__init__(message)
        self.min_queue_depth = min_queue_depth
        self.retry_after_s = retry_after_s


class ServeGateway:
    """Asyncio front end over one `Runtime`.

    Usage::

        gw = ServeGateway(runtime, shed_watermark=8)
        gw.start()                      # spawn the drive loop
        gw.submit(first_batch)          # stage arrivals (may raise
        ...                             #   GatewayOverloaded)
        async for kind, *rest in gw.stream(cid): ...
        records = await gw.drain()      # stop accepting, finish, close

    `streams` accumulates per-(cid, turn_idx) emissions: token-id lists on
    the engine backend (concatenated chunk payloads — byte-identical to the
    engine's own `sampled_tokens`), per-turn count lists on the simulator
    (one entry per completed turn). A `recovery` event resets the
    interrupted turn's key; replay then re-streams it.
    """

    def __init__(self, runtime: Runtime, *,
                 shed_watermark: Optional[int] = None,
                 max_events_per_tick: int = 64):
        self.runtime = runtime
        self.shed_watermark = shed_watermark
        self.max_events_per_tick = int(max_events_per_tick)
        # (cid, turn_idx) -> accumulated emission (ids or per-turn counts)
        self.streams: Dict[Tuple[int, int], List[int]] = {}
        # cid -> logical time of the first streamed token ever observed
        self.first_token_t: Dict[int, float] = {}
        self.done_cids: set = set()
        self.n_shed = 0
        self.n_submitted = 0
        self.events_seen: Counter = Counter()
        self._pending: List[Conversation] = []
        self._queues: Dict[int, asyncio.Queue] = {}
        self._accepting = True
        self._task: Optional[asyncio.Task] = None
        self._unsub = runtime.bus.subscribe(self._on_event)

    # ----- event-bus subscriber ---------------------------------------------
    def _on_event(self, ev: ServeEvent):
        self.events_seen[ev.kind] += 1
        if ev.kind == EV_TOKENS:
            key = (ev.cid, ev.turn_idx)
            buf = self.streams.setdefault(key, [])
            if "tokens" in ev.data:          # engine: actual token ids
                buf.extend(ev.data["tokens"])
                payload: Any = ev.data["tokens"]
            else:                            # simulator: turn-level count
                buf.append(int(ev.data["n_tokens"]))
                payload = ev.data["n_tokens"]
            self.first_token_t.setdefault(ev.cid, ev.t)
            self._q(ev.cid).put_nowait(("tokens", ev.turn_idx, payload))
        elif ev.kind == EV_RECOVERY:
            # deterministic replay will re-stream this in-flight turn from
            # scratch: drop the stale accumulation and tell consumers
            self.streams.pop((ev.cid, ev.turn_idx), None)
            self._q(ev.cid).put_nowait(("rewind", ev.turn_idx))
        elif ev.kind == EV_SESSION and ev.data.get("state") == DONE:
            self.done_cids.add(ev.cid)
            self._q(ev.cid).put_nowait(("done",))

    def _q(self, cid: int) -> asyncio.Queue:
        q = self._queues.get(cid)
        if q is None:
            q = self._queues[cid] = asyncio.Queue()
        return q

    # ----- admission (with circuit breaker) ---------------------------------
    def submit(self, convs: List[Conversation]) -> "ServeGateway":
        """Stage conversations for live injection at the next drive tick.
        Sheds (raises `GatewayOverloaded`) when every live node's admission
        queue exceeds the watermark — overload refuses new work, it never
        crashes work already admitted."""
        if not self._accepting:
            raise RuntimeError(
                "gateway is draining: new submissions are not accepted")
        if self.shed_watermark is not None:
            live = self.runtime.view.nodes()
            depths = {n.node_id: n.queued_conversations for n in live}
            if live and all(d > self.shed_watermark
                            for d in depths.values()):
                self.n_shed += len(convs)
                # observed-drain hint off the SHALLOWEST live node: its
                # queue drains one conversation per (mean resident context
                # × observed TBT) — every factor is a NodeState read
                shallow = min(live, key=lambda n: n.queued_conversations)
                min_depth = shallow.queued_conversations
                if (shallow.observed_tbt_ema_s <= 0
                        or shallow.active_conversations <= 0):
                    retry_after = 0.0
                else:
                    mean_ctx = (shallow.active_kv_tokens
                                / shallow.active_conversations)
                    retry_after = (min_depth * mean_ctx
                                   * shallow.observed_tbt_ema_s)
                raise GatewayOverloaded(
                    f"shedding {len(convs)} conversation(s): every live "
                    f"node's admission queue exceeds the watermark "
                    f"{self.shed_watermark} (depths: {depths}); retry "
                    f"after queues drain"
                    + (f" (~{retry_after:.3f}s observed-drain hint)"
                       if retry_after > 0 else ""),
                    min_queue_depth=min_depth,
                    retry_after_s=retry_after)
        self._pending.extend(convs)
        self.n_submitted += len(convs)
        return self

    # ----- drive loop --------------------------------------------------------
    def start(self) -> "ServeGateway":
        if self._task is None:
            self._task = asyncio.ensure_future(self._drive())
        return self

    async def _drive(self):
        """Interleave staged submission with incremental event execution.
        Exits once draining AND the runtime heap and staging buffer are both
        empty. While accepting, an idle tick yields to the loop so live
        producers can stage more arrivals."""
        while True:
            if self._pending:
                batch, self._pending = self._pending, []
                self.runtime.submit(batch)
            n = self.runtime.run_pending(self.max_events_per_tick)
            if n == 0 and not self._pending and not self._accepting:
                break
            await asyncio.sleep(0)

    async def drain(self) -> list:
        """Stop accepting, finish all in-flight work, close the runtime and
        return its `ConversationRecord`s."""
        self._accepting = False
        if self._task is not None:
            await self._task
            self._task = None
        self.runtime.close()
        self._unsub()
        return self.runtime.results()

    # ----- consumption -------------------------------------------------------
    async def stream(self, cid: int):
        """Async generator over one conversation's live emissions:
        ``("tokens", turn_idx, payload)`` (payload: id list on the engine,
        int count on the sim), ``("rewind", turn_idx)`` after a failure
        rewound an in-flight turn, ending at the session's DONE transition.
        """
        q = self._q(cid)
        while True:
            item = await q.get()
            if item[0] == "done":
                return
            yield item

    # ----- observability -----------------------------------------------------
    @property
    def accepting(self) -> bool:
        return self._accepting

    def health(self) -> Dict[str, Any]:
        """Health/drain endpoint payload: gateway lifecycle plus the same
        per-node observables schedulers read — a read of owned state, not a
        parallel bookkeeping path."""
        nodes = {}
        for st in self.runtime.view._nodes.values():
            nodes[st.node_id] = {
                "role": st.role,
                "alive": st.alive,
                "lifecycle": st.lifecycle,
                "kv_headroom_tokens": st.kv_headroom_tokens,
                "queued_conversations": st.queued_conversations,
                "masked_forward_fraction": st.masked_forward_fraction,
            }
        return {
            "gateway": "accepting" if self._accepting else "draining",
            "runtime_state": self.runtime.runtime_state,
            "n_submitted": self.n_submitted,
            "n_shed": self.n_shed,
            "n_done": len(self.done_cids),
            "n_node_joins": self.events_seen.get(EV_NODE_JOIN, 0),
            "n_node_quarantines": self.events_seen.get(
                EV_NODE_QUARANTINE, 0),
            "events_seen": dict(self.events_seen),
            "nodes": nodes,
        }
