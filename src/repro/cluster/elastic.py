"""Observation-driven capacity management (§4.2 last paragraph): the same
two signals that drive placement drive scaling. No forecasting — the
autoscaler reacts to measured prefill backlog and aggregate KV pressure."""
from __future__ import annotations

import dataclasses
from typing import Optional

from .hardware import NodeCostModel
from .simulator import ClusterSimulator


@dataclasses.dataclass
class AutoscalerConfig:
    check_interval_s: float = 10.0
    kv_high_watermark: float = 0.85   # aggregate decoder KV utilization
    kv_low_watermark: float = 0.30
    prefill_backlog_high_s: float = 5.0
    provision_delay_s: float = 30.0   # time to bring a replica up
    max_decoders: int = 16
    min_decoders: int = 1


class Autoscaler:
    """Periodically inspects the ClusterView and adds/drains decoder
    replicas. Scale-out uses the same NodeCostModel as existing decoders
    (or a capped tier for heterogeneous growth)."""

    def __init__(self, sim: ClusterSimulator, decoder_cost: NodeCostModel,
                 cfg: Optional[AutoscalerConfig] = None):
        self.sim = sim
        self.cost = decoder_cost
        self.cfg = cfg or AutoscalerConfig()
        self.events = []
        self._pending = 0

    def start(self):
        self.sim.at(self.cfg.check_interval_s, self._tick)
        return self

    def _decoders(self):
        return [n for n in self.sim.nodes.values()
                if n.role == "decode" and n.alive]

    def _parked_admissions(self) -> int:
        """Conversations parked in ANY node's admission queue — work the
        event heap does not see (parked admissions wait for a pump, not a
        timer), so the tick re-arm must count it explicitly."""
        return sum(len(q) for q in self.sim._admission.values())

    def _tick(self):
        sim, cfg = self.sim, self.cfg
        decs = self._decoders()
        if decs:
            # KV pressure counts RESERVED tokens too: admitted-in-flight
            # work holds real headroom (kv_headroom_tokens subtracts it),
            # so ignoring it undercounts pressure exactly when a burst of
            # admissions is about to land and can trigger a scale-IN while
            # the cluster is filling up
            util = (sum(d.state.active_kv_tokens
                        + d.state.reserved_kv_tokens for d in decs)
                    / max(sum(d.state.kv_capacity_tokens for d in decs), 1))
            n_live = len(decs) + self._pending
            if util > cfg.kv_high_watermark and n_live < cfg.max_decoders:
                self._pending += 1
                self.events.append((sim.now, "scale_out_requested", util))

                def up():
                    self._pending -= 1
                    nid = sim.add_decoder(self.cost)
                    self.events.append((sim.now, "scale_out_ready", nid))

                sim.at(sim.now + cfg.provision_delay_s, up)
            elif util < cfg.kv_low_watermark and len(decs) > cfg.min_decoders:
                # drain: stop new bindings by retiring the emptiest decoder
                # once it holds no live conversations AND no parked
                # admissions — then route the retirement through the shared
                # failure/drain contract (Runtime._drain_dead_node) so
                # anything that parked in the same event instant is
                # re-placed through its original scheduler decision point
                # instead of rotting in a dead queue (the old path flipped
                # `alive` directly and stranded parked work)
                cand = min(decs, key=lambda d: d.state.active_conversations)
                if (cand.state.active_conversations == 0
                        and len(sim._admission[cand.node_id]) == 0
                        and len(decs) > cfg.min_decoders):
                    cand.alive = False
                    cand.state.alive = False
                    sim._drain_dead_node(cand.node_id, sim.now)
                    self.events.append((sim.now, "scale_in", cand.node_id))
        # keep ticking while work remains ANYWHERE: heap events, or
        # conversations parked in admission queues (parked work generates
        # no events until something pumps it — a tick that stops on an
        # empty heap can strand it forever)
        if sim._events or self._parked_admissions():
            sim.at(sim.now + cfg.check_interval_s, self._tick)
