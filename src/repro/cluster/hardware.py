"""Hardware cost/energy models for the cluster runtime.

Two model families:
  * `A40Tier` — calibrated to the paper's measured constants (§5.1: ~25k
    input tok/s prefiller, ~1k output tok/s decoder, ~300k KV tokens,
    300W TDP, 200W capped tier) so the evaluation reproduces Fig. 10–13.
  * `TPUv5eTier` — the TPU adaptation (197 TFLOP/s bf16, 819 GB/s HBM,
    ~50 GB/s/link ICI) used by the roofline analysis and the heterogeneous
    mapping on TPU tiers (DESIGN.md §3).

The decode-side model is deliberately *structural*, not predictive: iteration
latency = max(compute, memory) + chunked-prefill interference, where the
memory term reads the batch's ACTIVE KV bytes — reproducing §3.2's findings
(memory-bound saturation at high batch×context; collocation overhead governed
by context once KV reads dominate; power caps marginal in the saturated
regime, Fig. 8).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.signals import PrefillLatencyCurve


@dataclasses.dataclass(frozen=True)
class HardwareTier:
    name: str
    peak_flops: float          # bf16 FLOP/s at full power
    hbm_bw: float              # bytes/s
    hbm_bytes: float
    link_bw: float             # bytes/s inter-node (KV transfer)
    tdp_w: float
    idle_w: float
    power_cap_w: Optional[float] = None  # None = uncapped

    # efficiency knobs (calibrated once, offline — these are the "profiled"
    # constants of §3.1/§3.2, not runtime predictions)
    prefill_eff: float = 0.53  # fraction of peak the prefill matmuls achieve
    #                            (calibrated: T_p(15k tokens) ~= 25k tok/s, §5.1)
    decode_bw_eff: float = 0.55
    iter_overhead_s: float = 0.004
    kv_transfer_setup_s: float = 0.008

    @property
    def effective_power_w(self) -> float:
        return min(self.power_cap_w or self.tdp_w, self.tdp_w)

    @property
    def compute_scale(self) -> float:
        """Compute throughput under a power cap (≈ linear in the cap above
        ~1/2 TDP for these parts; Fig. 7)."""
        return self.effective_power_w / self.tdp_w

    def capped(self, watts: float) -> "HardwareTier":
        return dataclasses.replace(self, power_cap_w=watts,
                                   name=f"{self.name}@{int(watts)}W")


@dataclasses.dataclass(frozen=True)
class ServedModelProfile:
    """Cost-relevant constants of the served model (qwen3-0.6b by default).

    `kv_bytes_per_token` is the TRUE cache footprint (drives capacity: 300k
    tokens on a 44GB A40, matching §5.1). `kv_read_bytes_per_token` is the
    CALIBRATED effective bytes the decode iteration reads per cached token —
    anchored so T_d ≈ 1k output tok/s at the workload operating point
    (batch≈16, ctx≈15k), the paper's measured §5.1 constant. The gap vs the
    raw footprint reflects vLLM's paged-attention read efficiency at their
    operating point; we reproduce the measurement, not re-derive it."""
    name: str = "qwen3-0.6b"
    n_params: float = 0.6e9
    kv_bytes_per_token: float = 28 * 8 * 128 * 2 * 2  # L*kv*hd*(k+v)*bf16
    kv_read_bytes_per_token: float = 20e3
    bytes_per_param: float = 2.0

    @property
    def param_bytes(self) -> float:
        return self.n_params * self.bytes_per_param

    @property
    def flops_per_token(self) -> float:
        return 2.0 * self.n_params


# link_bw: KV moves between replicas stage through host memory (LMCache-style
# disaggregation manager), well below raw PCIe — calibrated so the transfer
# fraction of TTFT matches Fig. 3 (~17% at 32k inputs).
A40 = HardwareTier(name="A40", peak_flops=149.7e12, hbm_bw=696e9,
                   hbm_bytes=44.98e9, link_bw=14e9, tdp_w=300.0, idle_w=60.0)
A40_CAPPED = A40.capped(200.0)

TPU_V5E = HardwareTier(name="TPUv5e", peak_flops=197e12, hbm_bw=819e9,
                       hbm_bytes=16e9, link_bw=50e9, tdp_w=220.0, idle_w=55.0)
TPU_V5E_CAPPED = TPU_V5E.capped(150.0)


class NodeCostModel:
    """Per-node cost/energy model used by the event simulator."""

    def __init__(self, tier: HardwareTier, model: ServedModelProfile,
                 chunk_tokens: int = 8192):
        self.tier = tier
        self.model = model
        self.chunk_tokens = chunk_tokens

    # ----- prefill (compute-bound; §3.1) --------------------------------------
    def prefill_s(self, n_tokens: int, cached_prefix: int = 0) -> float:
        """TTFT for a prefill of `n_tokens` with `cached_prefix` tokens
        already in the local prefix cache (near-constant cost when the prefix
        hits — Fig. 2)."""
        new = max(n_tokens - cached_prefix, 0)
        flops = new * self.model.flops_per_token
        # quadratic attention term over the full context (dominates >~10k)
        ctx = n_tokens
        attn = 2.0 * new * ctx * (28 * 16 * 128)  # L*H*hd score+pv flops
        rate = self.tier.peak_flops * self.tier.prefill_eff * self.tier.compute_scale
        return (flops + attn) / rate + 0.003

    def prefill_curve(self, max_len: int = 32768) -> PrefillLatencyCurve:
        """The offline-profiled deterministic curve (observable signal #1)."""
        pts = [2 ** i for i in range(7, 16) if 2 ** i <= max_len] + [max_len]
        lat = [self.prefill_s(L) for L in pts]
        curve, _ = PrefillLatencyCurve.fit(pts, lat)
        return curve

    def prefill_tokens_per_s(self, typical_len: int = 15_000) -> float:
        return typical_len / self.prefill_s(typical_len)

    # ----- decode (memory-bound; §3.2) ----------------------------------------
    def decode_iteration_s(self, batch: int, active_kv_tokens: int,
                           prefill_chunk_tokens: int = 0,
                           cached_chunk: bool = True) -> float:
        """One continuous-batching iteration: every decoding sequence emits a
        token; up to chunk_tokens of pending (append-)prefill ride along.
        Memory term reads params once + all active KV; power caps do NOT
        scale it (Fig. 8). Collocated prefill chunks add a compute term an
        order of magnitude smaller when the prefix cache hits (Fig. 5)."""
        if batch == 0 and prefill_chunk_tokens == 0:
            return 0.0
        mem_bytes = (self.model.param_bytes
                     + active_kv_tokens * self.model.kv_read_bytes_per_token)
        t_mem = mem_bytes / (self.tier.hbm_bw * self.tier.decode_bw_eff)
        t_comp = (batch * self.model.flops_per_token
                  / (self.tier.peak_flops * self.tier.prefill_eff
                     * self.tier.compute_scale))
        t = max(t_mem, t_comp) + self.tier.iter_overhead_s
        if prefill_chunk_tokens:
            pf_flops = prefill_chunk_tokens * self.model.flops_per_token
            if not cached_chunk:
                # cold prefix: the chunk effectively reprocesses accumulated
                # context, not just the append (Fig. 5: ~an order of
                # magnitude worse than a prefix-cache hit)
                pf_flops *= 9.0
            t += pf_flops / (self.tier.peak_flops * self.tier.prefill_eff
                             * self.tier.compute_scale)
        return t

    def decode_tokens_per_s(self, batch: int, mean_ctx: int) -> float:
        it = self.decode_iteration_s(batch, batch * mean_ctx)
        return batch / it if it > 0 else 0.0

    # ----- KV transfer (linear; §3.1 / Fig. 3) --------------------------------
    def kv_transfer_s(self, n_tokens: int) -> float:
        return (self.tier.kv_transfer_setup_s
                + n_tokens * self.model.kv_bytes_per_token / self.tier.link_bw)

    # ----- KV capacity ---------------------------------------------------------
    def kv_capacity_tokens(self) -> int:
        usable = self.tier.hbm_bytes - 1.15 * self.model.param_bytes - 2e9
        return int(usable / self.model.kv_bytes_per_token)

    # ----- energy ---------------------------------------------------------------
    def power_w(self, utilization: float, memory_bound: bool = False) -> float:
        """Instantaneous draw. Uncapped accelerators clock up to ~85% TDP
        even in memory-bound phases — wasted watts, since HBM-bound
        throughput doesn't need them. A power cap harvests exactly that
        waste with marginal latency effect (Figs. 8/13) — the structural
        fact the heterogeneous mapping exploits (§4.3)."""
        u = min(max(utilization, 0.0), 1.0)
        peak = self.tier.effective_power_w
        if memory_bound:
            peak = min(peak, 0.85 * self.tier.tdp_w)
        return self.tier.idle_w + u * (peak - self.tier.idle_w)
