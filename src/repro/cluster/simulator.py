"""Discrete-event cluster simulator for conversation-level serving.

The simulator owns all *mechanism* — prefill queues, continuous-batching
decode iterations, chunked prefill interleave, KV transfers, tool-call
timers, prefix caches, energy integration, failures — and delegates every
*placement* decision to a `repro.core.Scheduler` through the observable
`ClusterView` only. The same scheduler classes drive the real JAX engine
(`repro.engine`), so policy code is exercised identically at both scales.

Fidelity notes (mapped to the paper):
 * Prefiller: FIFO job queue; job latency from the offline-profiled curve
   (§3.1); chunked so energy/util integrate smoothly.
 * Decoder: iteration-level continuous batching. Iteration latency from
   NodeCostModel.decode_iteration_s(batch, active KV bytes, prefill chunk)
   — reproducing Fig. 4/5 (memory saturation, collocation interference,
   prefix-cache effects).
 * Remote turn-2+ prefill (AMPD-wrong / FullDisagg) pays the bidirectional
   KV move (§2.2) and, for FullDisagg, the full-context recompute.
 * Failures: a dead decoder's conversations recover by deterministic replay
   — re-prefill the journaled context on the prefiller and rebind; exactly
   ConServe's one-shot mechanism, reused (DESIGN.md §5).
 * Decode rotation: decoder iterations are single-token and jobs leave the
   batch the moment their output completes, so the simulator is structurally
   a continuous rotation — conversation ends pump the admission queue at the
   iteration (= chunk cut) where the slot freed, `Scheduler.select_refill`
   orders mid-tail refills through the shared `Runtime._pump`, and the
   engine's lane observables (`masked_forward_fraction`,
   `slot_busy_fraction`) are maintained on `NodeState` at this fidelity too
   (masked forwards are 0 by construction; see `_iterate`).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.conversation import Conversation, TurnView, view_of
from repro.core.events import (EV_NODE_FAILURE, EV_RECOVERY, EV_TOKENS,
                               EV_TURN_FINISH)
from repro.core.metrics import ConversationRecord, TurnRecord
from repro.core.runtime import (Admission, AdmissionQueue, DECODING, DONE,
                                PREFILLING, PrefixKVPool, Runtime,
                                ServeSession, TOOL_WAIT, TRANSFERRING)
from repro.core.scheduler import Scheduler
from repro.core.signals import NODE_ACTIVE, ClusterView, NodeState

from .hardware import NodeCostModel

# Simulated nodes are KV-headroom-limited by default; a finite slot count is
# opt-in (SimNode.n_slots) because slot exhaustion is an engine-level
# artifact the cost model has no analogue for unless declared.
UNBOUNDED_SLOTS = 1 << 30


# --------------------------------------------------------------------------- #
# Node runtime state
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class PrefillJob:
    cid: int
    turn_idx: int
    n_tokens: int            # tokens to (re)compute
    context_tokens: int      # total context after this prefill
    enqueued_s: float
    on_done: Callable[[float], None]
    extra_busy_s: float = 0.0  # KV I/O the node stalls on (remote turns: the
    #                            inbound history read + outbound write-back,
    #                            §5.5's "memory-heavy work on the prefiller")
    warm_prefix: bool = False  # turn-1 prefix served from the node's prefix
    #                            KV pool (observed hit at admission): only
    #                            n_tokens past the pooled preamble are
    #                            compute; the cost model's cached_prefix
    #                            (context - n_tokens) covers the rest


@dataclasses.dataclass
class DecodeJob:
    cid: int
    turn_idx: int
    remaining_prefill: int   # append tokens still to chunk through
    remaining_decode: int
    context_tokens: int      # current KV length for this conversation
    turn_arrival_s: float
    first_token_s: Optional[float] = None
    cold_prefix: bool = False


@dataclasses.dataclass
class SimNode:
    node_id: int
    role: str                          # "prefill" | "decode" | "mixed"
    cost: NodeCostModel
    n_slots: Optional[int] = None      # finite KV slot count (None=unbounded)
    # token budget for the node-level prefix KV pool (0 = no pool), SEPARATE
    # from kv_capacity — same contract as ReplicaEngine.prefix_pool_tokens.
    # The simulator's pool stores no rows (caches=None), only the observed
    # token volume + reuse counters, keyed by preamble identity; it ages
    # under the same shared eviction rule as the engine's.
    prefix_pool_tokens: int = 0
    prefix_pool: Optional[PrefixKVPool] = None
    state: NodeState = None
    prefill_q: List[PrefillJob] = dataclasses.field(default_factory=list)
    decode_jobs: Dict[int, DecodeJob] = dataclasses.field(default_factory=dict)
    busy_until_s: float = 0.0
    iterating: bool = False
    slow_factor: float = 1.0           # straggler injection
    alive: bool = True
    # incarnation counter: bumped at every revival so completion callbacks
    # dispatched against a PREVIOUS incarnation read as stale (the node
    # died and rejoined while the work was notionally in flight)
    gen: int = 0
    # energy accounting
    energy_j: float = 0.0
    last_energy_t: float = 0.0
    busy_s: float = 0.0

    def integrate_energy(self, now: float, active_power_w: float):
        dt = max(now - self.last_energy_t, 0.0)
        self.energy_j += dt * active_power_w
        self.last_energy_t = now


# --------------------------------------------------------------------------- #
# Simulator
# --------------------------------------------------------------------------- #
class ClusterSimulator(Runtime):
    def __init__(self, scheduler: Scheduler, nodes: List[SimNode],
                 chunk_tokens: int = 8192, decoder_chunk_tokens: int = 2944,
                 track_token_times: bool = False,
                 tool_deadline_s: Optional[float] = None,
                 tool_timeout_action: str = "evict",
                 strict_accounting: bool = False,
                 max_transfer_retries: int = 3,
                 transfer_retry_backoff_s: float = 0.01,
                 quarantine_k: Optional[float] = None,
                 quarantine_window: int = 3,
                 quarantine_rejoin_k: Optional[float] = None):
        """tool_deadline_s / tool_timeout_action: TOOL_WAIT watchdog, same
        contract as EngineServer — off by default (None); "evict" frees the
        waiting conversation's KV for parked work (the tool return re-admits
        by deterministic replay, the dead-binding path), "fail" raises
        loudly. Nothing parks forever on a tool that never returns.
        strict_accounting: engine-parity drift detection — at every
        conversation end, assert the structural accounting invariants
        (`check_accounting`).
        max_transfer_retries / transfer_retry_backoff_s: bound on one-shot
        KV-transfer attempts per binding, same contract (and same
        exhaustion error) as EngineServer — see `inject_transfer_faults`.
        quarantine_k / quarantine_window / quarantine_rejoin_k: the
        observed-straggler quarantine trigger (Runtime contract; None
        disables it) — see EngineServer for the semantics."""
        assert tool_timeout_action in ("evict", "fail")
        self.sched = scheduler
        self.tool_deadline_s = tool_deadline_s
        self.tool_timeout_action = tool_timeout_action
        self.strict_accounting = strict_accounting
        self.max_transfer_retries = int(max_transfer_retries)
        self.transfer_retry_backoff_s = float(transfer_retry_backoff_s)
        self.quarantine_k = quarantine_k
        self.quarantine_window = int(quarantine_window)
        self.quarantine_rejoin_k = quarantine_rejoin_k
        self.nodes = {n.node_id: n for n in nodes}
        for n in nodes:
            cap = n.cost.kv_capacity_tokens()
            n.state = NodeState(node_id=n.node_id, role=n.role,
                                kv_capacity_tokens=cap,
                                slot_capacity=n.n_slots or UNBOUNDED_SLOTS)
            if n.prefix_pool_tokens > 0 and n.prefix_pool is None:
                n.prefix_pool = PrefixKVPool(n.prefix_pool_tokens)
        self.chunk_tokens = chunk_tokens
        self.decoder_chunk_tokens = decoder_chunk_tokens
        self.track_token_times = track_token_times
        curve = nodes[0].cost.prefill_curve()
        self.view = ClusterView({n.node_id: n.state for n in nodes}, curve)

        self._events: List[Tuple[float, int, Callable]] = []
        self._seq = itertools.count()
        self.now = 0.0
        self.records: Dict[int, ConversationRecord] = {}
        self.sessions: Dict[int, ServeSession] = {}
        self._admission: Dict[int, AdmissionQueue] = {
            n.node_id: AdmissionQueue(n.node_id) for n in nodes}
        self._convs: Dict[int, Conversation] = {}
        self._bound: Dict[int, int] = {}
        self._turn_recs: Dict[int, List[TurnRecord]] = {}
        self.kv_transfer_bytes = 0.0
        self.n_kv_transfers = 0
        self.bind_counts: Dict[int, int] = {}
        self.log: List[str] = []
        # conversations evicted by the tool-deadline watchdog: their KV is
        # gone but the binding is remembered; tool return recovers by replay
        self._evicted: set = set()
        self.n_tool_evictions = 0
        # one-shot KV-transfer fault state (engine parity)
        self._bind_attempts: Dict[int, int] = {}
        self._transfer_fault_budget = 0
        self.n_transfer_retries = 0

    # ----- admission (Runtime contract) ----------------------------------------
    def _can_admit(self, node_id: int, adm: Admission) -> bool:
        """Ground truth for the cost-model backend: the node is alive, has a
        free KV slot (finite only when declared) and enough token headroom
        for the work's context. Work that can never fit fails loudly."""
        st = self.nodes[node_id].state
        if self._never_fits(node_id, adm):
            # mirror the engine's (and SlotKVCache.acquire()'s) message
            # style: name the conversation, the node, and the headroom it
            # could never fit into — at offer time, not from a later pump
            raise RuntimeError(
                f"conversation {adm.cid} can never fit on node {node_id}: "
                f"needs {adm.need_tokens} KV tokens but the node holds "
                f"{st.kv_capacity_tokens} total ({st.used_slots}/"
                f"{st.slot_capacity} slots used, {st.kv_headroom_tokens} KV "
                f"tokens of headroom); no amount of queueing or refill can "
                f"admit it")
        return (st.alive and st.free_slots > 0
                and st.kv_headroom_tokens >= adm.need_tokens)

    def _never_fits(self, node_id: int, adm: Admission) -> bool:
        return adm.need_tokens > self.nodes[node_id].state.kv_capacity_tokens

    def _reserve(self, st: NodeState, need_tokens: int):
        """Admitted work holds its slot + token reservation until the KV
        actually lands (_start_turn turn 0 converts reserved -> active)."""
        st.used_slots += 1
        st.reserved_kv_tokens += need_tokens

    # ----- event plumbing ------------------------------------------------------
    def at(self, t: float, fn: Callable):
        heapq.heappush(self._events, (max(t, self.now), next(self._seq), fn))

    def call_at(self, t: float, fn: Callable) -> "ClusterSimulator":
        """Engine-parity alias for `at` (the hook chaos drivers arm
        time-scheduled faults through on either backend)."""
        self.at(t, fn)
        return self

    @property
    def now_s(self) -> float:
        return self.now

    def run(self, until: Optional[float] = None):
        self.run_pending(until=until)
        if until is None:
            self.close()  # flushes idle energy, then rejects late submits
        else:
            for n in self.nodes.values():
                n.integrate_energy(self.now, n.cost.tier.idle_w)
        return self

    def run_pending(self, max_events: Optional[int] = None,
                    until: Optional[float] = None) -> int:
        """Incremental drive (Runtime contract): pop up to `max_events`
        pending events without closing, so staged submissions keep landing
        between calls. An event past `until` stays in the heap."""
        n = 0
        while self._events and (max_events is None or n < max_events):
            if until is not None and self._events[0][0] > until:
                break
            t, _, fn = heapq.heappop(self._events)
            self.now = t
            fn()
            n += 1
        return n

    def close(self):
        # flush idle energy to the end of the run before sealing the clock
        for n in self.nodes.values():
            n.integrate_energy(self.now, n.cost.tier.idle_w)
        super().close()

    # ----- workload entry -------------------------------------------------------
    def submit(self, convs: List[Conversation]):
        self._assert_accepting()
        for c in convs:
            self._convs[c.cid] = c
            self.records[c.cid] = ConversationRecord(c.cid, c.arrival_s)
            self._make_session(c.cid, c.arrival_s)
            self._turn_recs[c.cid] = []
            self.at(c.arrival_s, lambda c=c: self._on_arrival(c))
        return self

    # ----- arrival / prefill ------------------------------------------------------
    def _on_arrival(self, conv: Conversation):
        pl = self.sched.place_first_prefill(view_of(conv), self.view)
        node = self.nodes[pl.node_id]
        if node.role == "mixed":
            # collocated: the conversation RESIDES on the mixed node from its
            # first prefill chunk on, so arrival itself passes admission
            self._offer(pl.node_id,
                        Admission(conv.cid, conv.first_input_len,
                                  lambda nid, conv=conv:
                                  self._admit_arrival(conv, nid),
                                  kind="arrival"),
                        self.now)
            return
        # dedicated prefiller: jobs stream through a FIFO without holding
        # long-term KV residency; backpressure applies at the decoder bind
        self._admit_arrival(conv, pl.node_id)

    # ----- prefix KV pool (simulator mirror) -----------------------------------
    def _pool_key(self, conv: Conversation):
        """The simulator's pool key is the preamble IDENTITY — it has no
        token bytes to content-hash (the engine keys on `prefix_hash` of the
        actual tokens; the trace generator guarantees the two coincide:
        same (preamble_id, length) => byte-identical prefix)."""
        if conv.preamble_id is None or conv.preamble_tokens <= 0:
            return None
        return (conv.preamble_id, conv.preamble_tokens)

    def _pool_prefix_hit(self, node: SimNode, conv: Conversation) -> int:
        """OBSERVED pool hit at admission time: the pooled preamble length
        this turn-1 prefill job skips (0 = miss / no pool / no preamble).
        A hit records on the entry's reuse counters — it feeds the job."""
        key = self._pool_key(conv)
        if key is None or node.prefix_pool is None:
            return 0
        if node.prefix_pool.get(key) is None:  # get() records the hit
            return 0
        self._sync_pool_state(node)
        return conv.preamble_tokens

    def _pool_populate(self, node: SimNode, conv: Conversation):
        """Miss-path completion: install the preamble's token volume under
        the shared eviction rule (no-op if another conversation populated
        it first, or the node died while the job was in flight)."""
        key = self._pool_key(conv)
        if key is None or node.prefix_pool is None or not node.alive:
            return
        node.prefix_pool.put(key, None, conv.preamble_tokens,
                             conv.preamble_tokens)
        self._sync_pool_state(node)

    def _sync_pool_state(self, node: SimNode):
        """Mirror the node's prefix-pool ground truth into the NodeState
        observables (same mirror contract as the engine backend)."""
        pool = node.prefix_pool
        if pool is None:
            return
        st = node.state
        st.pooled_prefix_tokens = pool.pooled_tokens
        st.pooled_prefix_entries = pool.n_entries
        st.pooled_prefix_hits = pool.total_hits
        st.pooled_prefix_evictions = pool.n_evictions

    def _admit_arrival(self, conv: Conversation, node_id: int):
        node = self.nodes[node_id]
        mixed = node.node_id if node.role == "mixed" else None
        if mixed is not None:
            # the slot lands the FULL context either way (pooled rows fold
            # in); only the prefill COMPUTE charge below shrinks on a hit
            self._reserve(node.state, conv.first_input_len)
        self.sessions[conv.cid].transition(PREFILLING, self.now)
        pooled = self._pool_prefix_hit(node, conv)

        def on_done(t, conv=conv, node=node, mixed=mixed, pooled=pooled):
            if not pooled:
                self._pool_populate(node, conv)
            self._after_first_prefill(conv, t, mixed_node=mixed)

        job = PrefillJob(
            cid=conv.cid, turn_idx=0,
            n_tokens=conv.first_input_len - pooled,
            context_tokens=conv.first_input_len, enqueued_s=self.now,
            on_done=on_done, warm_prefix=pooled > 0)
        self._enqueue_prefill(node, job)

    def _enqueue_prefill(self, node: SimNode, job: PrefillJob):
        node.state.queued_prefill_tokens += job.n_tokens
        if node.role == "mixed":
            # collocated: prefill chunks ride the decode iterations
            dj = DecodeJob(cid=job.cid, turn_idx=job.turn_idx,
                           remaining_prefill=job.n_tokens, remaining_decode=0,
                           context_tokens=job.context_tokens,
                           turn_arrival_s=job.enqueued_s,
                           cold_prefix=not job.warm_prefix)
            dj._prefill_done = job.on_done  # type: ignore[attr-defined]
            node.decode_jobs[(job.cid << 8) + job.turn_idx] = dj
            self._kick_iteration(node)
        else:
            node.prefill_q.append(job)
            self._kick_prefiller(node)

    def _kick_prefiller(self, node: SimNode):
        if node.iterating or not node.prefill_q or not node.alive:
            return
        node.iterating = True
        gen = node.gen
        job = node.prefill_q.pop(0)
        dur = node.cost.prefill_s(job.context_tokens,
                                  cached_prefix=job.context_tokens - job.n_tokens)
        dur = dur * node.slow_factor + job.extra_busy_s
        node.integrate_energy(self.now, node.cost.tier.idle_w)

        def done():
            if not node.alive:
                # the prefiller died mid-job: the computation never landed —
                # re-place the job on a healthy prefill-capable node
                node.iterating = False
                node.state.queued_prefill_tokens -= job.n_tokens
                self._replace_prefill_job(node.node_id, job)
                return
            if node.gen != gen:
                # the node died AND rejoined while the job was in flight:
                # the computation still never landed — re-place it, but
                # leave the NEW incarnation's iterating flag alone (it owns
                # the flag now)
                node.state.queued_prefill_tokens -= job.n_tokens
                self._replace_prefill_job(node.node_id, job)
                return
            node.integrate_energy(
                self.now, node.cost.power_w(1.0, memory_bound=False))
            node.busy_s += dur
            node.state.queued_prefill_tokens -= job.n_tokens
            node.iterating = False
            job.on_done(self.now)
            self._kick_prefiller(node)

        self.at(self.now + dur, done)

    def _after_first_prefill(self, conv: Conversation, t: float,
                             mixed_node: Optional[int] = None):
        if mixed_node is not None:
            # collocated: the conversation already lives on the mixed replica
            self._bound[conv.cid] = mixed_node
            g = self.nodes[mixed_node].gen
            self.at(t, lambda: self._start_turn(conv, 0, mixed_node,
                                                arrival_t=conv.arrival_s,
                                                gen=g))
            return
        # the one-shot KV binding passes admission on the chosen decoder:
        # when it is full (no slot / headroom for this context) the binding
        # parks in the decoder's admission queue and is re-offered as
        # conversations end — backpressure, not silent overcommit
        pl = self.sched.bind_decoder(view_of(conv), self.view)
        self._offer(pl.node_id,
                    Admission(conv.cid, conv.first_input_len,
                              lambda nid, conv=conv, t=t,
                              kv=pl.kv_transfer:
                              self._bind(conv, nid, max(t, self.now), kv)),
                    t)

    def _bind(self, conv: Conversation, node_id: int, t: float,
              kv_transfer: bool):
        dec = self.nodes[node_id]
        if kv_transfer and self._transfer_fault_budget > 0:
            # armed one-shot transfer fault (engine parity): the attempt
            # dies before any KV lands; the binding retries with
            # exponential backoff on a decoder the scheduler chooses
            # FRESH at retry time, bounded by max_transfer_retries
            self._transfer_fault_budget -= 1
            self.n_transfer_retries += 1
            attempt = self._bind_attempts.get(conv.cid, 0) + 1
            self._bind_attempts[conv.cid] = attempt
            if attempt > self.max_transfer_retries:
                raise RuntimeError(
                    f"KV transfer for conversation {conv.cid} failed on "
                    f"{attempt} consecutive attempts "
                    f"(max_transfer_retries={self.max_transfer_retries}); "
                    f"giving up loudly")
            self.sessions[conv.cid].transition(TRANSFERRING, t)
            backoff = self.transfer_retry_backoff_s * (2 ** (attempt - 1))
            self.log.append(
                f"t={t:.3f} KV transfer to node {node_id} FAILED for cid "
                f"{conv.cid} (attempt {attempt}); retrying in "
                f"{backoff:.3f}s")

            def retry(conv=conv):
                pl = self.sched.bind_decoder(view_of(conv), self.view)
                self._offer(pl.node_id,
                            Admission(conv.cid, conv.first_input_len,
                                      lambda nid, kv=pl.kv_transfer:
                                      self._bind(conv, nid, self.now, kv)),
                            self.now)

            self.at(t + backoff, retry)
            return
        self._bind_attempts.pop(conv.cid, None)
        self._reserve(dec.state, conv.first_input_len)
        self._bound[conv.cid] = node_id
        self.sessions[conv.cid].node_id = node_id
        self.bind_counts[node_id] = self.bind_counts.get(node_id, 0) + 1
        self.records[conv.cid].n_kv_transfers += int(kv_transfer)
        delay = 0.0
        if kv_transfer:
            self.sessions[conv.cid].transition(TRANSFERRING, t)
            delay = self._transfer(conv.first_input_len, dec)
        self.at(t + delay, lambda g=dec.gen: self._start_turn(
            conv, 0, node_id, arrival_t=conv.arrival_s, gen=g))

    def _transfer(self, n_tokens: int, node: SimNode) -> float:
        self.n_kv_transfers += 1
        self.kv_transfer_bytes += n_tokens * node.cost.model.kv_bytes_per_token
        return node.cost.kv_transfer_s(n_tokens)

    # ----- turns -----------------------------------------------------------------
    def _start_turn(self, conv: Conversation, turn_idx: int, node_id: int,
                    prefilled: bool = True, cold: bool = False,
                    arrival_t: Optional[float] = None,
                    gen: Optional[int] = None):
        """Begin decoding turn `turn_idx` on `node_id`. If not `prefilled`,
        the turn's append tokens still need (chunked) prefill on the node.
        `arrival_t` is when the turn became RUNNABLE (tool returned /
        conversation arrived) — queue and transfer waits count toward its
        TTFT. `gen` is the target's incarnation at schedule time: a landing
        on a node that died (even if it has since rejoined cold — the KV
        never arrived) recovers by replay."""
        node = self.nodes[node_id]
        if not node.alive or (gen is not None and node.gen != gen):
            # the node died while this start was in flight (e.g. mid
            # KV-transfer): the failure's victim scan only sees installed
            # decode jobs, so the landing itself must observe the corpse —
            # recover by replay instead of stranding a job nothing iterates
            self._recover(conv, turn_idx)
            return
        turn = conv.turns[turn_idx]
        ctx = sum(t.append_tokens + t.output_tokens
                  for t in conv.turns[: turn_idx + 1]) - turn.output_tokens
        if turn_idx == 0:
            node.state.active_kv_tokens += conv.first_input_len
            node.state.active_conversations += 1
            # admission reservation becomes live KV
            node.state.reserved_kv_tokens = max(
                0, node.state.reserved_kv_tokens - conv.first_input_len)
        self.sessions[conv.cid].transition(DECODING, self.now, force=True)
        dj = DecodeJob(cid=conv.cid, turn_idx=turn_idx,
                       remaining_prefill=0 if prefilled else turn.append_tokens,
                       remaining_decode=turn.output_tokens,
                       context_tokens=ctx,
                       turn_arrival_s=self.now if arrival_t is None
                       else arrival_t,
                       cold_prefix=cold)
        node.decode_jobs[(conv.cid << 8) + turn_idx] = dj
        self._kick_iteration(node)

    def _on_turn_tokens_done(self, node: SimNode, dj: DecodeJob):
        conv = self._convs[dj.cid]
        turn = conv.turns[dj.turn_idx]
        rec = TurnRecord(turn_idx=dj.turn_idx, arrival_s=dj.turn_arrival_s,
                         first_token_s=dj.first_token_s or self.now,
                         last_token_s=self.now,
                         n_output_tokens=turn.output_tokens)
        self._turn_recs[conv.cid].append(rec)
        # the simulator emits at turn granularity (it owns token COUNTS,
        # not token bytes): one tokens event per completed turn
        self._publish(EV_TOKENS, self.now, cid=conv.cid,
                      turn_idx=dj.turn_idx, node_id=node.node_id,
                      n_tokens=turn.output_tokens,
                      first_token_s=rec.first_token_s)
        self._publish(EV_TURN_FINISH, self.now, cid=conv.cid,
                      turn_idx=dj.turn_idx, node_id=node.node_id,
                      n_output_tokens=turn.output_tokens)
        node.state.active_kv_tokens += turn.output_tokens
        if dj.turn_idx + 1 < conv.n_turns:
            self.sessions[conv.cid].transition(TOOL_WAIT, self.now)
            self.sessions[conv.cid].turn_idx = dj.turn_idx + 1
            self.at(self.now + turn.tool_time_s,
                    lambda: self._on_turn_arrival(conv, dj.turn_idx + 1))
            if self.tool_deadline_s is not None:
                dl = self.now + self.tool_deadline_s
                self.at(dl, lambda: self._tool_watchdog(
                    conv, dj.turn_idx + 1, dl))
        else:
            self._finish_conversation(conv, node)

    def _finish_conversation(self, conv: Conversation, node: SimNode):
        rec = self.records[conv.cid]
        rec.turns = self._turn_recs[conv.cid]
        self.sessions[conv.cid].transition(DONE, self.now, force=True)
        node.state.active_kv_tokens -= conv.peak_context_tokens()
        node.state.active_conversations -= 1
        node.state.used_slots = max(0, node.state.used_slots - 1)
        self.sched.on_conversation_end(conv.cid, self.view)
        if self.strict_accounting:
            self.check_accounting()
        # occupancy freed: re-offer parked admissions (backpressure)
        self._pump(node.node_id, self.now)
        # a DRAINING node whose last resident tail just left re-activates
        self._maybe_finish_draining(node.node_id, self.now)

    def _on_turn_arrival(self, conv: Conversation, turn_idx: int):
        bound = self._bound[conv.cid]
        if conv.cid in self._evicted:
            # tool returned to an evicted binding (deadline watchdog freed
            # the KV): re-admit by replay, exactly the dead-binding path
            self._evicted.discard(conv.cid)
            self._recover(conv, turn_idx)
            return
        if not self.nodes[bound].alive:
            # tool returned to a dead binding: lazy recovery by replay
            self._recover(conv, turn_idx)
            return
        turn = conv.turns[turn_idx]
        ctx = sum(t.append_tokens + t.output_tokens
                  for t in conv.turns[:turn_idx])
        ready_t = self.now
        tv = TurnView(cid=conv.cid, turn_idx=turn_idx,
                      append_tokens=turn.append_tokens, context_tokens=ctx)
        pl = self.sched.place_turn(tv, bound, self.view)
        self.records[conv.cid].n_kv_transfers += int(pl.kv_transfer)
        if pl.node_id == bound:
            # local append-prefill, chunked into the decoder's iterations
            node = self.nodes[bound]
            node.state.active_kv_tokens += turn.append_tokens
            self.sessions[conv.cid].transition(PREFILLING, self.now)
            self._start_turn(conv, turn_idx, bound, prefilled=False)
            return
        # remote turn prefill (AMPD wrong prediction / FullDisagg)
        self.records[conv.cid].n_remote_turns += 1
        if pl.kv_transfer:
            self.sessions[conv.cid].transition(TRANSFERRING, self.now)
        pf = self.nodes[pl.node_id]
        dec = self.nodes[bound]
        dec.state.active_kv_tokens += turn.append_tokens
        full_recompute = self.sched.name == "full_disagg"
        n_new = (ctx + turn.append_tokens) if full_recompute else turn.append_tokens
        # decoder -> prefiller history read + eventual write-back: this KV
        # I/O occupies the prefiller (memory-heavy work mixed into its
        # compute-bound pipeline — §5.5's utilization-drop mechanism)
        t_out = self._transfer(ctx, pf) if pl.kv_transfer else 0.0
        t_back = self._transfer(ctx + turn.append_tokens, dec) \
            if pl.kv_transfer else 0.0
        extra = 0.0 if full_recompute else t_out + t_back

        def enqueue():
            self.sessions[conv.cid].transition(PREFILLING, self.now)
            job = PrefillJob(
                cid=conv.cid, turn_idx=turn_idx, n_tokens=n_new,
                context_tokens=ctx + turn.append_tokens, enqueued_s=self.now,
                on_done=lambda t: back(), extra_busy_s=extra)
            self._enqueue_prefill(pf, job)

        def back():
            # prefiller -> decoder write-back of the new (and, for AMPD,
            # reused) KV entries
            self.at(self.now + t_back,
                    lambda g=dec.gen: self._start_turn(conv, turn_idx,
                                                       bound,
                                                       prefilled=True,
                                                       arrival_t=ready_t,
                                                       gen=g))

        self.at(self.now + t_out, enqueue)

    # ----- decoder iterations -------------------------------------------------
    def _kick_iteration(self, node: SimNode):
        if node.iterating or not node.decode_jobs or not node.alive:
            return
        node.iterating = True
        self._iterate(node)

    def _iterate(self, node: SimNode):
        if not node.decode_jobs or not node.alive:
            node.iterating = False
            if node.alive:
                # the rotation just went idle: a DRAINING node whose last
                # resident tail left re-activates here (the finish hook ran
                # while `iterating` was still set)
                self._maybe_finish_draining(node.node_id, self.now)
            return
        gen = node.gen
        jobs = list(node.decode_jobs.values())
        decoding = [j for j in jobs if j.remaining_prefill == 0
                    and j.remaining_decode > 0]
        prefilling = [j for j in jobs if j.remaining_prefill > 0]
        batch = len(decoding)
        active_kv = sum(j.context_tokens for j in jobs)
        chunk_budget = self.decoder_chunk_tokens if node.role != "prefill" \
            else self.chunk_tokens
        chunk = 0
        cold = False
        for j in prefilling:
            take = min(j.remaining_prefill, chunk_budget - chunk)
            chunk += take
            cold = cold or j.cold_prefix
            if chunk >= chunk_budget:
                break
        dur = node.cost.decode_iteration_s(batch, active_kv, chunk,
                                           cached_chunk=not cold)
        dur *= node.slow_factor
        node.integrate_energy(self.now, node.cost.tier.idle_w)

        def step_done():
            if not node.alive:
                node.iterating = False
                return
            if node.gen != gen:
                # the node died and rejoined mid-iteration: this completion
                # belongs to the previous incarnation (its jobs were
                # recovered at the failure); the new incarnation owns the
                # iterating flag
                return
            node.integrate_energy(
                self.now, node.cost.power_w(1.0, memory_bound=(batch > 0)))
            node.busy_s += dur
            # observable TBT signal (straggler detection reads this)
            if batch:
                ema = node.state.observed_tbt_ema_s
                node.state.observed_tbt_ema_s = (0.9 * ema + 0.1 * dur) \
                    if ema else dur
                # one observed decode chunk: advance the straggler-
                # quarantine machine on the EMA that just updated
                self._observe_chunk_tbt(node.node_id, self.now)
                # rotation observables, mirroring the engine's lane-step
                # counters: the cost model emits one token per live job per
                # iteration and jobs leave the batch the moment they finish,
                # so the simulator is structurally already a continuous
                # rotation — every emitting lane-step is live
                # (masked_forward_fraction == 0 by construction) and
                # slot_busy_fraction tracks batch over declared slots
                node.state.decode_scan_steps += 1
                node.state.decode_lane_steps_emitting += batch
                node.state.decode_lane_steps_live += batch
            # consume prefill chunk
            left = chunk
            for j in list(prefilling):
                take = min(j.remaining_prefill, left)
                j.remaining_prefill -= take
                left -= take
                if getattr(j, "_prefill_done", None) is not None:
                    # mixed-node turn-1 prefill counts toward the queue signal
                    node.state.queued_prefill_tokens = max(
                        0, node.state.queued_prefill_tokens - take)
                if j.remaining_prefill == 0 and j.remaining_decode == 0:
                    # collocated turn-1 prefill job completed
                    cb = getattr(j, "_prefill_done", None)
                    node.decode_jobs.pop((j.cid << 8) + j.turn_idx, None)
                    if cb:
                        cb(self.now)
                if left <= 0:
                    break
            # emit one token per decoding sequence
            for j in decoding:
                if j.first_token_s is None:
                    j.first_token_s = self.now
                j.remaining_decode -= 1
                j.context_tokens += 1
                if j.remaining_decode == 0:
                    node.decode_jobs.pop((j.cid << 8) + j.turn_idx, None)
                    self._on_turn_tokens_done(node, j)
            self._iterate(node)

        self.at(self.now + dur, step_done)

    # ----- faults / elasticity (observation-driven) ----------------------------
    def inject_failure(self, node_id: int, at_s: float):
        self.at(at_s, lambda: self._fail(node_id))
        return self

    # engine-API parity, so benchmarks drive both backends uniformly
    fail_replica = inject_failure

    def _fail(self, node_id: int):
        node = self.nodes[node_id]
        if not node.alive:
            raise RuntimeError(f"node {node_id} failed twice")
        node.integrate_energy(self.now, node.cost.tier.idle_w)
        node.alive = False
        node.state.alive = False
        self._lifecycle_streaks.pop(node_id, None)
        victims = {j.cid for j in node.decode_jobs.values()}
        # sever TOOL_WAIT bindings to the corpse NOW: lazy alive-checks at
        # tool return would be fooled by a revival (the new incarnation's KV
        # is cold — the old slot contents are gone). The existing evicted ->
        # replay path in _on_turn_arrival re-admits them honestly.
        for cid, bnid in self._bound.items():
            if (bnid == node_id and cid not in victims
                    and cid not in self._evicted
                    and self.sessions[cid].state == TOOL_WAIT
                    and not self.records[cid].done):
                self._evicted.add(cid)
        # a dead mixed node's in-iteration turn-1 prefills vanish with the
        # decode jobs: release their share of the backlog observable (the
        # victims re-place it on whatever node recovery chooses)
        for dj in node.decode_jobs.values():
            if getattr(dj, "_prefill_done", None) is not None:
                node.state.queued_prefill_tokens = max(
                    0, node.state.queued_prefill_tokens - dj.remaining_prefill)
        node.decode_jobs.clear()
        if node.prefix_pool is not None:
            # pooled preamble rows die with the node's KV: recovered and
            # future conversations re-populate through the normal miss path
            # (the cumulative hit/eviction counters survive)
            node.prefix_pool.invalidate_all()
        node.state.active_kv_tokens = 0
        node.state.active_conversations = 0
        node.state.used_slots = 0
        node.state.reserved_kv_tokens = 0
        self._sync_pool_state(node)
        self.log.append(f"t={self.now:.1f} node {node_id} FAILED; "
                        f"recovering {len(victims)} in-flight conversations "
                        f"by replay (tool-waiting ones recover lazily)")
        self._publish(EV_NODE_FAILURE, self.now, node_id=node_id,
                      n_victims=len(victims))
        # a dead prefiller's queued jobs never ran: re-place each on a
        # healthy prefill-capable node (mid-flight jobs re-place from their
        # completion callback, which observes the death)
        if node.prefill_q:
            jobs, node.prefill_q = list(node.prefill_q), []
            for job in jobs:
                node.state.queued_prefill_tokens -= job.n_tokens
                self._replace_prefill_job(node_id, job)
        # work parked in the dead node's admission queue will never be
        # pumped — re-place each through the SAME scheduler decision point
        # that placed it originally (shared Runtime mechanism; raises loudly
        # when the target is dead too, or no healthy candidate exists)
        self._drain_dead_node(node_id, self.now)
        for cid in victims:
            conv = self._convs[cid]
            done_turns = len(self._turn_recs[cid])
            self._recover(conv, min(done_turns, conv.n_turns - 1))

    def revive_node(self, node_id: int, at_s: float):
        """Schedule a failed node's COLD rejoin at logical time `at_s` (same
        contract as EngineServer.recover_replica): resident counters are
        already zero from the failure and stay zero, pooled prefix rows stay
        invalidated, cumulative counters (busy_s, energy_j, bind_counts,
        replayed_prefill_tokens, pool hit/eviction totals) survive. The node
        re-enters `ClusterView.nodes()` and every admission queue is pumped.
        Reviving an alive node raises; fail -> revive -> fail cycles are
        legal (per-node incarnation generations keep stale completions from
        the previous life off the new one)."""
        self.at(at_s, lambda: self._revive(node_id))
        return self

    # engine-API parity, so benchmarks drive both backends uniformly
    recover_replica = revive_node

    def _revive(self, node_id: int):
        node = self.nodes[node_id]
        if node.alive:
            raise RuntimeError(
                f"node {node_id} is already alive; only a failed node can "
                f"rejoin")
        node.alive = True
        node.state.alive = True
        node.state.lifecycle = NODE_ACTIVE
        # the observed-TBT history belongs to the previous incarnation
        node.state.observed_tbt_ema_s = 0.0
        self._lifecycle_streaks.pop(node_id, None)
        node.gen += 1
        node.iterating = False
        node.last_energy_t = self.now  # the dead interval drew no power
        self._rejoin_node(node_id, self.now, reason="from_dead")

    def inject_slowdown(self, node_id: int, factor: float,
                        at_s: Optional[float] = None):
        """Stretch `node_id`'s measured iteration/prefill durations by
        `factor` (slow, not wrong: outputs stay byte-identical). The
        stretched durations feed `observed_tbt_ema_s`, which is exactly
        what the observed-straggler quarantine conditions on. `factor=1.0`
        ends the slowdown. Applies now, or at logical `at_s` if given."""
        def arm():
            self.nodes[node_id].slow_factor = float(factor)
        if at_s is None:
            arm()
        else:
            self.at(at_s, arm)
        return self

    def inject_transfer_faults(self, n: int = 1):
        """Make the next `n` KV-transfer binds fail once each (engine-API
        parity). Each faulted bind retries with bounded exponential backoff;
        `max_transfer_retries` consecutive faults on one conversation
        exhaust the budget and raise loudly."""
        self._transfer_fault_budget += int(n)
        return self

    def _node_has_inflight(self, node_id: int) -> bool:
        node = self.nodes[node_id]
        if node.decode_jobs or node.prefill_q or node.iterating:
            return True
        # TOOL_WAIT sessions still bound here hold slots (resident tails)
        return any(bnid == node_id and not self.records[cid].done
                   and cid not in self._evicted
                   for cid, bnid in self._bound.items())

    def check_accounting(self) -> None:
        """Structural occupancy invariants, checked after every conversation
        completes when `strict_accounting=True` (engine-API parity). Every
        quantity here is a counter the simulator already maintains."""
        for nid, node in self.nodes.items():
            st = node.state
            q = len(self._admission[nid])
            if st.queued_conversations != q:
                raise AssertionError(
                    f"node {nid}: queued_conversations={st.queued_conversations}"
                    f" but admission queue holds {q}")
            for name in ("active_kv_tokens", "active_conversations",
                         "used_slots", "reserved_kv_tokens"):
                v = getattr(st, name)
                if v < 0:
                    raise AssertionError(f"node {nid}: {name}={v} < 0")
            if not node.alive:
                if q or st.active_kv_tokens or st.active_conversations \
                        or st.used_slots or st.reserved_kv_tokens:
                    raise AssertionError(
                        f"dead node {nid} holds resident state: "
                        f"queue={q} kv={st.active_kv_tokens} "
                        f"convs={st.active_conversations} "
                        f"slots={st.used_slots} "
                        f"reserved={st.reserved_kv_tokens}")
            elif st.lifecycle != NODE_ACTIVE and q:
                raise AssertionError(
                    f"{st.lifecycle} node {nid} holds {q} parked "
                    f"admissions; quarantine must drain them to peers")

    def _replace_admission(self, adm: Admission, now: float) -> Optional[int]:
        """Re-place one admission drained off a dead node through the same
        decision point that placed it (Runtime._drain_dead_node guards the
        returned target)."""
        cv = view_of(self._convs[adm.cid])
        if adm.kind == "arrival":
            return self.sched.place_first_prefill(cv, self.view).node_id
        return self.sched.bind_decoder(cv, self.view).node_id

    def _replace_prefill_job(self, dead_node_id: int, job: PrefillJob):
        """Re-enqueue a dead prefiller's job on a healthy prefill-capable
        node. The job's completion callback carries its continuation, so
        the downstream bind/turn plumbing is untouched."""
        pl = self.sched.place_first_prefill(view_of(self._convs[job.cid]),
                                            self.view)
        target = self.nodes[pl.node_id]
        if not target.alive:
            raise RuntimeError(
                f"re-placement of prefill job for conversation {job.cid} "
                f"off dead node {dead_node_id} chose node {pl.node_id}, "
                f"which is also dead; schedulers must place on live nodes "
                f"only")
        self.log.append(f"t={self.now:.1f} re-placed prefill job "
                        f"(cid {job.cid}) from dead node {dead_node_id} "
                        f"onto node {pl.node_id}")
        self._enqueue_prefill(target, job)

    def _tool_watchdog(self, conv: Conversation, next_idx: int,
                       deadline_t: float):
        """TOOL_WAIT deadline (same contract as EngineServer._tool_watchdog):
        fires `tool_deadline_s` after the session entered TOOL_WAIT before
        turn `next_idx`. No-op when the tool already returned (or the
        binding died/was evicted in the meantime); otherwise evicts the
        conversation's KV for waiting work, or fails loudly."""
        cid = conv.cid
        sess = self.sessions[cid]
        if (sess.state != TOOL_WAIT or sess.turn_idx != next_idx
                or cid in self._evicted):
            return
        bound = self._bound.get(cid)
        if bound is None or not self.nodes[bound].alive:
            return  # binding already dead; the tool return replays anyway
        if self.tool_timeout_action == "fail":
            raise RuntimeError(
                f"conversation {cid} exceeded the tool deadline: turn "
                f"{next_idx} still TOOL_WAIT at t={deadline_t:.3f} "
                f"(tool_deadline_s={self.tool_deadline_s}); "
                f"tool_timeout_action='fail'")
        node = self.nodes[bound]
        ctx = sum(t.append_tokens + t.output_tokens
                  for t in conv.turns[:next_idx])
        node.state.active_kv_tokens -= ctx
        node.state.active_conversations -= 1
        node.state.used_slots = max(0, node.state.used_slots - 1)
        self._evicted.add(cid)
        self.records[cid].n_tool_evictions += 1
        self.n_tool_evictions += 1
        self.log.append(
            f"t={deadline_t:.3f} tool deadline: evicted cid {cid} from "
            f"node {bound} (turn {next_idx} still waiting); KV freed for "
            f"parked work, tool return re-admits by replay")
        self._pump(bound, self.now)
        self._maybe_finish_draining(bound, self.now)

    def _recover(self, conv: Conversation, turn_idx: int):
        """Deterministic replay: re-prefill the journaled context on the
        prefiller, rebind to a healthy decoder (exactly ConServe's one-shot
        mechanism), then resume the interrupted/pending turn. Replay tokens
        are charged to the prefiller's `replayed_prefill_tokens`, and the
        trigger->resume latency to the record's `recovery_latency_s`."""
        self.records[conv.cid].recovered = True
        t0 = self.now
        # the interrupted turn never emitted (the sim publishes at turn
        # completion only), but subscribers tracking in-flight state still
        # observe the rewind from the owned transition point
        self._publish(EV_RECOVERY, self.now, cid=conv.cid, turn_idx=turn_idx)
        self.sessions[conv.cid].transition(PREFILLING, self.now, force=True)
        ctx = sum(t.append_tokens + t.output_tokens
                  for t in conv.turns[:turn_idx]) \
            + conv.turns[turn_idx].append_tokens
        pl = self.sched.place_first_prefill(view_of(conv), self.view)
        pf = self.nodes[pl.node_id]
        pf.state.replayed_prefill_tokens += ctx

        def redo(t, conv=conv, turn_idx=turn_idx, ctx=ctx):
            pl2 = self.sched.bind_decoder(view_of(conv), self.view)
            dec2 = self.nodes[pl2.node_id]
            self._bound[conv.cid] = pl2.node_id
            self.sessions[conv.cid].node_id = pl2.node_id
            self.bind_counts[pl2.node_id] = \
                self.bind_counts.get(pl2.node_id, 0) + 1
            dec2.state.active_kv_tokens += ctx
            dec2.state.active_conversations += 1
            dec2.state.used_slots += 1
            delay = self._transfer(ctx, dec2) if pl2.kv_transfer else 0.0
            self.at(t + delay,
                    lambda g=dec2.gen: self._resume_turn(
                        conv, turn_idx, pl2.node_id, t0, gen=g))

        job = PrefillJob(cid=conv.cid, turn_idx=turn_idx, n_tokens=ctx,
                         context_tokens=ctx, enqueued_s=self.now,
                         on_done=redo)
        self._enqueue_prefill(pf, job)

    def _resume_turn(self, conv: Conversation, turn_idx: int, node_id: int,
                     recover_t0: Optional[float] = None,
                     gen: Optional[int] = None):
        node = self.nodes[node_id]
        if not node.alive or (gen is not None and node.gen != gen):
            # the recovery target itself died before the resume landed:
            # recover again toward whatever is still healthy (the first
            # attempt's latency stays open — only successful resumes close)
            self._recover(conv, turn_idx)
            return
        turn = conv.turns[turn_idx]
        if recover_t0 is not None:
            self.records[conv.cid].recovery_latency_s.append(
                self.now - recover_t0)
        self.sessions[conv.cid].transition(DECODING, self.now, force=True)
        dj = DecodeJob(cid=conv.cid, turn_idx=turn_idx, remaining_prefill=0,
                       remaining_decode=turn.output_tokens,
                       context_tokens=sum(
                           t.append_tokens + t.output_tokens
                           for t in conv.turns[:turn_idx]) + turn.append_tokens,
                       turn_arrival_s=self.now)
        node.decode_jobs[(conv.cid << 8) + turn_idx] = dj
        self._kick_iteration(node)

    def add_decoder(self, cost: NodeCostModel,
                    n_slots: Optional[int] = None) -> int:
        nid = max(self.nodes) + 1
        node = SimNode(node_id=nid, role="decode", cost=cost,
                       n_slots=n_slots, last_energy_t=self.now)
        cap = cost.kv_capacity_tokens()
        node.state = NodeState(node_id=nid, role="decode",
                               kv_capacity_tokens=cap,
                               slot_capacity=n_slots or UNBOUNDED_SLOTS)
        self.nodes[nid] = node
        self.view._nodes[nid] = node.state
        self._admission[nid] = AdmissionQueue(nid)
        self.log.append(f"t={self.now:.1f} scaled out: decoder {nid}")
        return nid

    # ----- results ----------------------------------------------------------------
    def total_energy_j(self) -> float:
        return sum(n.energy_j for n in self.nodes.values())

    def results(self) -> List[ConversationRecord]:
        return [r for r in self.records.values() if r.done]
