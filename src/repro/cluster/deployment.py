"""Deployment factories: build the node sets for each system configuration
(§5.1: four GPUs — 1 prefiller + 3 decoders for disaggregated systems, 4
mixed replicas for Collocated) on a chosen hardware tier, plus the
heterogeneous variant (full-power prefiller, capped decoders)."""
from __future__ import annotations

from typing import List, Optional

from repro.core.scheduler import Scheduler, make_scheduler

from .hardware import (A40, A40_CAPPED, HardwareTier, NodeCostModel,
                       ServedModelProfile)
from .simulator import ClusterSimulator, SimNode


def build_cluster(scheduler: Scheduler, *, n_prefill: int = 1,
                  n_decode: int = 3, n_mixed: int = 0,
                  prefill_tier: HardwareTier = A40,
                  decode_tier: HardwareTier = A40,
                  model: Optional[ServedModelProfile] = None,
                  decoder_chunk_tokens: int = 2944,
                  chunk_tokens: int = 8192,
                  **sim_kwargs) -> ClusterSimulator:
    """`sim_kwargs` pass through to ClusterSimulator (e.g. the failure
    contract's `tool_deadline_s` / `tool_timeout_action`)."""
    model = model or ServedModelProfile()
    nodes: List[SimNode] = []
    nid = 0
    for _ in range(n_prefill):
        nodes.append(SimNode(node_id=nid, role="prefill",
                             cost=NodeCostModel(prefill_tier, model,
                                                chunk_tokens)))
        nid += 1
    for _ in range(n_decode):
        nodes.append(SimNode(node_id=nid, role="decode",
                             cost=NodeCostModel(decode_tier, model,
                                                decoder_chunk_tokens)))
        nid += 1
    for _ in range(n_mixed):
        nodes.append(SimNode(node_id=nid, role="mixed",
                             cost=NodeCostModel(decode_tier, model,
                                                decoder_chunk_tokens)))
        nid += 1
    return ClusterSimulator(scheduler, nodes, chunk_tokens=chunk_tokens,
                            decoder_chunk_tokens=decoder_chunk_tokens,
                            **sim_kwargs)


def paper_deployment(system: str, *, heterogeneous: bool = False,
                     wrong_prediction_rate: float = 0.10,
                     seed: int = 0, **sim_kwargs) -> ClusterSimulator:
    """The four evaluated systems on the paper's 4-GPU box. `heterogeneous`
    caps the decoder tier to 200W (Fig. 13)."""
    dec_tier = A40_CAPPED if heterogeneous else A40
    if system == "collocated":
        sched = make_scheduler("collocated")
        return build_cluster(sched, n_prefill=0, n_decode=0, n_mixed=4,
                             decode_tier=dec_tier, **sim_kwargs)
    if system == "conserve":
        sched = make_scheduler("conserve")
    elif system == "full_disagg":
        sched = make_scheduler("full_disagg")
    elif system == "ampd":
        sched = make_scheduler("ampd",
                               wrong_prediction_rate=wrong_prediction_rate,
                               seed=seed)
    else:
        raise ValueError(system)
    return build_cluster(sched, n_prefill=1, n_decode=3,
                         decode_tier=dec_tier, **sim_kwargs)
