from .hardware import (A40, A40_CAPPED, TPU_V5E, TPU_V5E_CAPPED, HardwareTier,
                       NodeCostModel, ServedModelProfile)
from .simulator import ClusterSimulator, SimNode
from .deployment import build_cluster, paper_deployment
from .elastic import Autoscaler, AutoscalerConfig
