"""Pallas TPU kernel: causal flash attention for the compute-bound prefill
phase (the paper's turn-1 work).

Canonical TPU flash layout: grid (B, H, nQ, nK) with the KV-block axis
minor-most (sequential on TPU), online-softmax statistics carried in VMEM
scratch across KV blocks, MXU-shaped (block_q × head_dim) tiles. Causal
blocks above the diagonal are skipped via pl.when (no wasted MXU issue —
unlike the masked-jnp fallback, this kernel does NOT pay the 2× causal
overhead; see EXPERIMENTS.md §Perf)."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, window: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    # skip blocks fully above the causal diagonal / outside the window
    in_range = k_start <= q_start + block_q - 1
    if window:
        in_range &= (k_start + block_k - 1) > (q_start - window)

    @pl.when(in_range)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (block_q, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


def flash_prefill_attention(q, k, v, *, window: int = 0, block_q: int = 128,
                            block_k: int = 128, interpret: bool = True):
    """q,k,v: (B, H, S, D) — same head count (GQA expanded by caller).
    Causal (optionally sliding-window) attention. Returns (B, H, S, D)."""
    B, H, S, D = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, "pad S to block multiples"
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_flash_kernel, block_q=block_q,
                               block_k=block_k, window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            # m, l: (block_q, 1); acc: (block_q, D) — fp32 online stats in VMEM
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
