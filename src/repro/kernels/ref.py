"""Pure-jnp oracles for every Pallas kernel. Deliberately naive (materialized
scores, step-by-step recurrences) and written independently of the model
code so kernel sweeps test against a second implementation."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def causal_attention_ref(q, k, v, *, window: int = 0):
    """q,k,v: (B, S, H, D) (same head count — GQA expanded by caller).
    Full materialized causal softmax attention."""
    B, S, H, D = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(D))
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = j <= i
    if window:
        mask &= j > i - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q, k, v, lengths=None):
    """q: (B, H, D); k,v: (B, S, Hkv, D); lengths: (B,) valid KV lengths.
    One-token GQA attention."""
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bngd,bsnd->bngs", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(D))
    if lengths is not None:
        mask = jnp.arange(S)[None, None, None, :] < lengths[:, None, None, None]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngs,bsnd->bngd", p, v.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)


def wkv6_ref(r, k, v, logw, u, state):
    """Step-by-step WKV6 recurrence (the slow oracle).
    r,k,v: (B,S,H,hs); logw: (B,S,H,hs) (<0); u: (H,hs);
    state: (B,H,hs,hs) [key, value] layout. Returns (y, final_state)."""
    B, S, H, hs = r.shape
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def step(s_, t):
        rt, kt, vt, wt = rf[:, t], kf[:, t], vf[:, t], logw[:, t]
        a = jnp.einsum("bhi,bhv->bhiv", kt, vt)  # outer product
        y = (jnp.einsum("bhi,bhiv->bhv", rt, s_)
             + jnp.einsum("bhi,bhi->bh", rt, u[None] * kt)[..., None] * vt)
        s_new = jnp.exp(wt)[..., None] * s_ + a
        return s_new, y

    final, ys = jax.lax.scan(step, state.astype(jnp.float32),
                             jnp.arange(S))
    return ys.transpose(1, 0, 2, 3), final


def rglru_ref(log_a, b, h0):
    """Step-by-step gated linear recurrence: h_t = exp(log_a_t)*h_{t-1}+b_t.
    log_a, b: (B, S, W); h0: (B, W). Returns (h_all (B,S,W), h_final)."""
    a = jnp.exp(log_a.astype(jnp.float32))
    bf = b.astype(jnp.float32)

    def step(h, t):
        h = a[:, t] * h + bf[:, t]
        return h, h

    hT, hs = jax.lax.scan(step, h0.astype(jnp.float32), jnp.arange(b.shape[1]))
    return hs.transpose(1, 0, 2), hT
