"""Pallas TPU kernel: RG-LRU gated linear recurrence (RecurrentGemma).

Diagonal recurrence h_t = a_t*h_{t-1} + b_t is pure VPU work. The TPU
layout: grid (B, nW, nC) — channel blocks ride the lane dimension, the
chunk axis is minor-most/sequential with the carried state in VMEM scratch,
and each chunk runs a short fori_loop over its timesteps (VPU elementwise;
no MXU needed — this layer is bandwidth-bound by construction, which is why
the paper's low-power tier absorbs it so well)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, y_ref, hT_ref, h_scr, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[...]

    a = a_ref[0].astype(jnp.float32)  # (chunk, Wb)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        y_ref[0, t] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[0])
    h_scr[...] = h[None]

    @pl.when(ci == nc - 1)
    def _final():
        hT_ref[0] = h


def rglru_pallas(log_a, b, h0, *, chunk: int = 128, block_w: int = 512,
                 interpret: bool = True):
    """log_a, b: (B, S, W); h0: (B, W) f32. h_t = exp(log_a_t) h_{t-1} + b_t.
    Returns (h_all (B,S,W) f32, h_final (B,W) f32)."""
    B, S, W = log_a.shape
    chunk = min(chunk, S)
    block_w = min(block_w, W)
    assert S % chunk == 0 and W % block_w == 0
    nc, nw = S // chunk, W // block_w
    a = jnp.exp(log_a.astype(jnp.float32))

    kernel = functools.partial(_rglru_kernel, chunk=chunk)
    y, hT = pl.pallas_call(
        kernel,
        grid=(B, nw, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_w), lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((1, chunk, block_w), lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((1, block_w), lambda bi, wi, ci: (bi, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_w), lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((1, block_w), lambda bi, wi, ci: (bi, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        interpret=interpret,
    )(a, b.astype(jnp.float32), h0.astype(jnp.float32))
    return y, hT
