"""Pallas TPU kernel: flash-decode GQA attention — the memory-bound tail
phase ConServe pins to decoders. One query token per sequence reads a long
KV cache; the kernel streams KV blocks HBM->VMEM with online-softmax
accumulation, so HBM KV bandwidth is the only roofline term (matching §3.2's
characterization). GQA is handled by blocking over KV heads: the G query
heads sharing a KV head ride in one (G, D) tile against each (block_k, D)
KV tile — an MXU-shaped matmul even at decode.

Length trimming: the grid is a scalar-prefetch grid
(`pltpu.PrefetchScalarGridSpec`) whose KV-block index map clamps the block
index to each sequence's last *live* block — once `k_start >= valid_len`
the map revisits the previous block, so Pallas's revisit-elision never
issues the HBM->VMEM DMA for dead cache tail blocks. Callers that know a
static upper bound on the live lengths pass `max_len` and the grid itself
shrinks to `ceil(max_len / block_k)` KV steps.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, block_k: int, scale: float):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    b = pl.program_id(0)
    valid_len = len_ref[b]
    k_start = ki * block_k

    @pl.when(k_start < valid_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (block_k, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < valid_len, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


def flash_decode_attention(q, k, v, lengths=None, *, block_k: int = 256,
                           max_len: int | None = None,
                           interpret: bool = True):
    """q: (B, H, D); k,v: (B, S, Hkv, D); lengths: (B,) valid KV lengths
    (None = all S valid). `max_len` is an optional STATIC upper bound on
    `lengths`; when given, the KV grid only spans ceil(max_len / block_k)
    blocks instead of S / block_k. Returns (B, H, D)."""
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    block_k = min(block_k, S)
    assert S % block_k == 0, "pad cache length to a block multiple"
    nk = S // block_k
    if max_len is not None:
        if lengths is None and max_len < S:
            raise ValueError(
                "max_len < S with lengths=None would silently truncate "
                "attention to the first max_len positions; pass lengths")
        nk = max(1, min(nk, -(-int(max_len) // block_k)))
    scale = 1.0 / math.sqrt(D)
    if lengths is None:
        lengths = jnp.full((B,), S, jnp.int32)
    lengths = lengths.astype(jnp.int32)
    qg = q.reshape(B, Hkv, G, D)

    def kv_block(b, n, ki, lens):
        # clamp to the last live block: dead tail blocks revisit it, which
        # Pallas elides — no HBM fetch past each sequence's valid length.
        last_live = jnp.maximum(pl.cdiv(lens[b], block_k) - 1, 0)
        return (b, jnp.minimum(ki, last_live), n, 0)

    kernel = functools.partial(_decode_kernel, block_k=block_k, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # lengths ride in SMEM ahead of the grid
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, n, ki, lens: (b, n, 0, 0)),
            pl.BlockSpec((1, block_k, 1, D), kv_block),
            pl.BlockSpec((1, block_k, 1, D), kv_block),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, n, ki, lens: (b, n, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(lengths, qg, k, v)
    return out.reshape(B, H, D)
