"""Jit'd public wrappers around the Pallas kernels with backend dispatch:
on TPU the compiled kernels run natively (interpret=False); elsewhere they
execute in interpret mode (for validation) or fall back to the jnp
reference path (`impl="xla"`). The model substrate uses the XLA path for
the multi-device dry-run (Pallas inside GSPMD is a per-backend concern);
kernels are selectable via `attention_impl` for single-replica serving."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import flash_decode_attention
from .prefill_attention import flash_prefill_attention
from .rglru_kernel import rglru_pallas
from .rwkv6_kernel import wkv6_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("window", "impl"))
def prefill_attention(q, k, v, *, window: int = 0, impl: str = "pallas"):
    """q,k,v: (B, S, H, D) — causal (optionally sliding-window) attention."""
    if impl == "xla":
        return ref.causal_attention_ref(q, k, v, window=window)
    out = flash_prefill_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), window=window, interpret=not _on_tpu())
    return out.transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("impl", "max_len"))
def decode_attention(q, k, v, lengths=None, *, impl: str = "pallas",
                     max_len: int | None = None):
    """q: (B,H,D); k,v: (B,S,Hkv,D); lengths: (B,). Flash-decode GQA.
    `max_len` (static) bounds the live lengths so the kernel grid only
    spans the live KV prefix (dead tail blocks are never fetched)."""
    if lengths is None and max_len is not None and max_len < k.shape[1]:
        raise ValueError("max_len < S requires lengths (see "
                         "flash_decode_attention)")
    if impl == "xla":
        if max_len is not None:
            s = min(k.shape[1], -(-int(max_len) // 128) * 128)
            k, v = k[:, :s], v[:, :s]
        return ref.decode_attention_ref(q, k, v, lengths)
    return flash_decode_attention(q, k, v, lengths, max_len=max_len,
                                  interpret=not _on_tpu())


@partial(jax.jit, static_argnames=("impl", "chunk"))
def wkv6(r, k, v, logw, u, state, *, chunk: int = 32, impl: str = "pallas"):
    """Chunk-parallel WKV6. Returns (y, final_state), both fp32."""
    if impl == "xla":
        return ref.wkv6_ref(r, k, v, logw, u, state)
    return wkv6_pallas(r, k, v, logw, u, state, chunk=chunk,
                       interpret=not _on_tpu())


@partial(jax.jit, static_argnames=("impl", "chunk"))
def rglru_scan(log_a, b, h0, *, chunk: int = 128, impl: str = "pallas"):
    """Gated linear recurrence h_t = exp(log_a_t) h_{t-1} + b_t."""
    if impl == "xla":
        return ref.rglru_ref(log_a, b, h0)
    return rglru_pallas(log_a, b, h0, chunk=chunk, interpret=not _on_tpu())
