"""Pallas TPU kernel: chunk-parallel WKV6 (RWKV6 "Finch" recurrence with
data-dependent per-channel decay).

TPU adaptation of the CUDA wkv6 kernel: instead of one-thread-per-channel
serial recurrence (a GPU-warp idiom with no TPU analogue), the sequence is
processed in chunks — intra-chunk interactions become small MXU matmuls with
a decay-weighted lower-triangular mask, and the (hs × hs) recurrent state is
carried in VMEM scratch across the chunk axis (grid minor-most = sequential
on TPU). All decay exponents are differences along time, so every exp()
argument is <= 0."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
                 s_scr, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0]

    r = r_ref[0, 0].astype(jnp.float32)   # (c, hs)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)   # log-decay, < 0
    u = u_ref[0].astype(jnp.float32)      # (1, hs) bonus
    S0 = s_scr[...]                        # (hs, hs)

    cum = jnp.cumsum(w, axis=0)           # (c, hs) inclusive
    e_t = cum - w                          # cum_{t-1}
    rd = r * jnp.exp(e_t)                  # decay-folded queries (exp <= 0)
    tot = cum[chunk - 1: chunk, :]         # (1, hs) total chunk decay
    kd = k * jnp.exp(tot - cum)            # decay-folded keys (exp <= 0)
    # intra-chunk scores need per-channel pairwise decay differences —
    # exp(e_t[t,i] - cum[j,i]) for j < t is <= 0 in the exponent, safe; the
    # (c, c, hs) tensor stays in VMEM because chunks are small (32/64).
    dmat = e_t[:, None, :] - cum[None, :, :]          # (c, c, hs)
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) \
        > jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    dmat = jnp.where(tri[..., None], dmat, -1e30)     # j<t only
    A = jnp.einsum("ti,ji,tji->tj", r, k, jnp.exp(dmat))
    diag = jnp.sum(r * (u * k), axis=1)               # bonus on the diagonal
    A = A + jnp.diag(diag)
    y = jax.lax.dot(A.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    y = y + jax.lax.dot(rd.astype(jnp.float32), S0,
                        preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: S1 = diag(exp(tot)) S0 + kd^T V
    S1 = jnp.exp(tot).T * S0 + jax.lax.dot(
        kd.T.astype(v.dtype), v, preferred_element_type=jnp.float32)
    s_scr[...] = S1

    @pl.when(ci == nc - 1)
    def _final():
        sT_ref[0, 0] = s_scr[...]


def wkv6_pallas(r, k, v, logw, u, state, *, chunk: int = 32,
                interpret: bool = True):
    """r,k,v,logw: (B, S, H, hs); u: (H, hs); state: (B, H, hs, hs) f32.
    Returns (y (B,S,H,hs) f32, final_state)."""
    B, S, H, hs = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, "pad S to chunk multiple"
    nc = S // chunk
    # layout: (B, H, S, hs) blocks of (1, 1, chunk, hs)
    tr = lambda x: x.transpose(0, 2, 1, 3)
    kernel = functools.partial(_wkv6_kernel, chunk=chunk)
    y, sT = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hs), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hs), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hs), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hs), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, hs), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, hs, hs), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hs), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, hs, hs), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, hs), jnp.float32),
            jax.ShapeDtypeStruct((B, H, hs, hs), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hs, hs), jnp.float32)],
        interpret=interpret,
    )(tr(r), tr(k), tr(v), tr(logw), u, state)
    return y.transpose(0, 2, 1, 3), sT
