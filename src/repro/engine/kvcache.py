"""Slot-based KV cache for the real JAX serving engine.

TPU-native adaptation of vLLM's paged KV (DESIGN.md §3): each replica owns
preallocated slot-major cache buffers — slot s is a contiguous max_ctx region
per layer. Contiguous regions suit the TPU's large sequential HBM reads;
page tables have no TPU analogue worth emulating. Conversations pin a slot
for their lifetime (exactly ConServe's binding), lengths are tracked
host-side, and reads beyond a slot's live length are masked via kv_lens.
"""
from __future__ import annotations

import hashlib
from functools import partial
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.runtime import (PrefixKVPool, PrefixPoolEntry,  # noqa: F401
                                prefix_eviction_order)
from repro.models.model import Model

GROWING = ("k", "v", "ckv", "krope")


def _is_growing(path) -> bool:
    names = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
    return names[-1] in GROWING and "cross" not in names


@partial(jax.jit, static_argnames=("slot", "length"))
def _write_slot(cache_leaf, new_leaf, slot: int, length: int, grouped: bool):
    """Write a prefilled (batch=1) cache into slot `slot` at [0, length)."""
    if grouped:  # (G, B, L, ...) <- (G, 1, length, ...)
        return jax.lax.dynamic_update_slice(
            cache_leaf, new_leaf.astype(cache_leaf.dtype),
            (0, slot, 0) + (0,) * (cache_leaf.ndim - 3))
    return jax.lax.dynamic_update_slice(
        cache_leaf, new_leaf.astype(cache_leaf.dtype),
        (slot, 0) + (0,) * (cache_leaf.ndim - 2))


def fold_decode_step(caches, updates, lens, mask, grouped, growing):
    """Pure, jit-safe fold of one decode step's cache updates: growing
    entries scatter at each slot's current length (dynamic ``.at[].set``),
    fixed states replace where ``mask`` is set. This is the function the
    fused donated decode step runs *inside* jit so XLA updates the cache
    buffers in place; `SlotKVCache.append_step` below keeps the original
    host-side copy path alive as the parity oracle.

    ``mask`` is the per-step LIVE mask, not just slot activity: the ragged
    scan passes ``emit & (step < remaining)``, so a slot whose per-slot
    chunk share is exhausted mid-scan stops folding here — its cache row,
    length, and fed-back token are all frozen from that step on while
    longer-running neighbors keep appending. A masked-out slot's row must
    be byte-identical afterwards (tests assert this), which is why every
    branch is a select against the old leaf rather than an unconditional
    write.

    caches/updates: pytrees; lens (n_slots,) int32 device array;
    mask (n_slots,) bool device array; grouped/growing: static bool trees.
    Returns the new caches pytree (same structure/shapes/dtypes)."""
    n_slots = mask.shape[0]

    def fold(cache_leaf, up_leaf, g, gr):
        if gr:
            idx_b = jnp.arange(n_slots)
            if g:  # (G, B, L, ...) <- (G, B, 1, ...)
                return cache_leaf.at[:, idx_b, lens].set(
                    jnp.where(
                        mask.reshape((1, -1) + (1,) * (up_leaf.ndim - 3)),
                        up_leaf[:, :, 0].astype(cache_leaf.dtype),
                        cache_leaf[:, idx_b, lens]))
            return cache_leaf.at[idx_b, lens].set(
                jnp.where(
                    mask.reshape((-1,) + (1,) * (up_leaf.ndim - 2)),
                    up_leaf[:, 0].astype(cache_leaf.dtype),
                    cache_leaf[idx_b, lens]))
        bdim = 1 if g else 0
        shape = [1] * cache_leaf.ndim
        shape[bdim] = n_slots
        return jnp.where(mask.reshape(shape),
                         up_leaf.astype(cache_leaf.dtype), cache_leaf)

    return jax.tree_util.tree_map(fold, caches, updates, grouped, growing)


def slice_slot_prefix(caches, slot, ctx: int, grouped, growing):
    """Pure, jit-safe read of ONE slot's cache rows, with growing entries
    trimmed to the static `ctx` bucket: growing leaves come back as
    (…, 1, ctx, …) views of the slot's prefix region, fixed states as the
    slot's (…, 1, …) row. `slot` may be a traced scalar — this is how the
    AOT-compiled append-prefill program reads its hot prefix *inside* the
    donated jit program, replacing the host-side `export_slot_full` copy
    on the serve path (that method survives as the eager oracle's input).
    Positions at/beyond the slot's live length hold stale bytes; callers
    mask them via kv_lens exactly as with the full-buffer view."""
    def take(leaf, g, gr):
        if gr:
            if g:  # (G, B, L, ...) -> (G, 1, ctx, ...)
                return jax.lax.dynamic_slice(
                    leaf, (0, slot, 0) + (0,) * (leaf.ndim - 3),
                    (leaf.shape[0], 1, min(ctx, leaf.shape[2]))
                    + leaf.shape[3:])
            return jax.lax.dynamic_slice(  # (B, L, ...) -> (1, ctx, ...)
                leaf, (slot, 0) + (0,) * (leaf.ndim - 2),
                (1, min(ctx, leaf.shape[1])) + leaf.shape[2:])
        if g:  # fixed state, grouped: (G, B, ...) -> (G, 1, ...)
            return jax.lax.dynamic_slice(
                leaf, (0, slot) + (0,) * (leaf.ndim - 2),
                (leaf.shape[0], 1) + leaf.shape[2:])
        return jax.lax.dynamic_slice(
            leaf, (slot,) + (0,) * (leaf.ndim - 1), (1,) + leaf.shape[1:])

    return jax.tree_util.tree_map(take, caches, grouped, growing)


def fold_prefill(caches, new_caches, slot, offset, grouped, growing):
    """Pure, jit-safe fold of a (batch=1) prefill result into slot `slot`:
    growing entries land at [offset, offset+S); fixed states replace the
    slot's row. Both `slot` and `offset` may be traced scalars — this is
    the same write `SlotKVCache.write_prefill` performs host-side, hoisted
    into the AOT-compiled prefill program so the donated cache pytree is
    scattered in place (zero host-side KV materialization per prefill).
    The written region may extend past the slot's live length (bucketed
    token padding); reads are masked via kv_lens, exactly as with the
    host-side write."""
    def put(leaf, new_leaf, g, gr):
        new_leaf = new_leaf.astype(leaf.dtype)
        if gr:
            start = ((0, slot, offset) + (0,) * (leaf.ndim - 3) if g
                     else (slot, offset) + (0,) * (leaf.ndim - 2))
        else:
            start = ((0, slot) + (0,) * (leaf.ndim - 2) if g
                     else (slot,) + (0,) * (leaf.ndim - 1))
        return jax.lax.dynamic_update_slice(leaf, new_leaf, start)

    return jax.tree_util.tree_map(put, caches, new_caches, grouped, growing)


class SlotKVCache:
    """Owns the cache pytree (batch dim = n_slots) plus per-slot lengths."""

    def __init__(self, model: Model, n_slots: int, max_ctx: int,
                 replica_id: Optional[int] = None):
        self.model = model
        self.cfg = model.cfg
        self.n_slots = n_slots
        self.max_ctx = max_ctx
        self.replica_id = replica_id  # diagnostics only (acquire() error)
        self.caches = model.init_cache(n_slots, max_ctx)
        self.lengths = np.zeros(n_slots, np.int32)
        self.active = np.zeros(n_slots, bool)
        self._grouped = jax.tree_util.tree_map_with_path(
            lambda p, l: l.ndim >= 4 and str(
                getattr(p[0], "key", p[0])) in ("groups", "self", "cross"),
            self.caches)
        self._growing = jax.tree_util.tree_map_with_path(
            lambda p, l: _is_growing(p), self.caches)

    # ----- slot management -----------------------------------------------------
    def acquire(self) -> int:
        free = np.flatnonzero(~self.active)
        if len(free) == 0:
            # Unreachable from the serve path: EngineServer admits every
            # slot-holding stage through the per-node admission queue
            # (repro.core.runtime) and only acquires after _can_admit saw a
            # free slot. Kept loud for direct misuse of the cache API.
            who = "?" if self.replica_id is None else self.replica_id
            raise RuntimeError(
                f"no free KV slots on replica {who}: "
                f"{int(self.active.sum())}/{self.n_slots} slots active, "
                f"{self.active_kv_tokens} live KV tokens; serve-path callers "
                f"must wait in the node's admission queue instead of "
                f"acquiring directly")
        s = int(free[0])
        self.active[s] = True
        self.lengths[s] = 0
        return s

    def release(self, slot: int):
        self.active[slot] = False
        self.lengths[slot] = 0

    def invalidate_all(self):
        """Replica failure: every slot's contents are gone at once. Host
        bookkeeping zeroes so the observables mirror the dead cache (strict
        accounting keeps checking dead replicas); the device buffers stay
        allocated — stale bytes on a dead replica are unreachable, and a
        revived replica would re-prefill before any read."""
        self.active[:] = False
        self.lengths[:] = 0

    @property
    def active_kv_tokens(self) -> int:
        return int(self.lengths[self.active].sum())

    def kv_lens(self) -> jnp.ndarray:
        return jnp.asarray(self.lengths)

    def positions(self) -> jnp.ndarray:
        return jnp.asarray(self.lengths)

    # ----- writes ----------------------------------------------------------------
    def write_prefill(self, slot: int, new_caches, length: int,
                      state_slot_batch1: bool = True):
        """Install a (batch=1) prefill result into `slot`: growing entries
        are copied into [0(or prev_len), ...); fixed states replace the slot's
        row. `length` = the slot's total live length afterwards. Host-side
        dispatch of the same `fold_prefill` the AOT prefill programs run
        in-program (this path is the eager oracle's write)."""
        prev = int(self.lengths[slot])
        self.caches = fold_prefill(self.caches, new_caches, slot, prev,
                                   self._grouped, self._growing)
        self.lengths[slot] = length

    def append_step(self, updates, emitted_mask: np.ndarray):
        """REFERENCE PATH: fold one decode step's cache updates in from the
        host side — growing entries land at each slot's current length;
        states replace. emitted_mask marks slots that actually decoded
        (others keep their state). The serving hot path runs the same fold
        *inside* the donated jit program (one dispatch per chunk, in-place);
        this per-token host-side version is the dispatch/copy baseline for
        parity tests and benchmarks — true math independence comes from the
        model-rollout oracles in the tests, not from this path."""
        self.caches = fold_decode_step(
            self.caches, updates, jnp.asarray(self.lengths),
            jnp.asarray(emitted_mask), self._grouped, self._growing)
        self.lengths[emitted_mask] += 1

    # ----- transfer --------------------------------------------------------------
    def export_slot(self, slot: int) -> Dict[str, Any]:
        """Extract one slot's live cache (for KV transfer between replicas)."""
        length = int(self.lengths[slot])

        def take(path, leaf, grouped, growing):
            if growing:
                return (leaf[:, slot: slot + 1, :length] if grouped
                        else leaf[slot: slot + 1, :length])
            return (leaf[:, slot: slot + 1] if grouped
                    else leaf[slot: slot + 1])

        tree = jax.tree_util.tree_map_with_path(
            lambda p, l, g, gr: take(p, l, g, gr),
            self.caches, self._grouped, self._growing)
        return {"caches": tree, "length": length}

    def import_slot(self, slot: int, package: Dict[str, Any]):
        self.write_prefill(slot, package["caches"], package["length"])

    def export_slot_full(self, slot: int):
        """Full-buffer prefix view of a slot (right-padded beyond the live
        length; callers mask with kv_lens + prefix_start=0)."""
        def take(path, leaf, grouped, growing):
            return leaf[:, slot:slot + 1] if grouped else leaf[slot:slot + 1]

        return jax.tree_util.tree_map_with_path(
            lambda p_, l, g, gr: take(p_, l, g, gr),
            self.caches, self._grouped, self._growing)

    def nbytes_of(self, package) -> int:
        return sum(l.size * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(package["caches"]))


# ----- prefix KV pool ---------------------------------------------------------
def prefix_hash(tokens: Sequence[int]) -> str:
    """Content hash of a token prefix — the pool key. Hashing the TOKENS
    (not a trace-level preamble id) means two conversations share pooled
    rows iff their prefix bytes are actually identical; a workload that
    lies about its preamble identity cannot poison another conversation's
    context."""
    arr = np.asarray(tokens, np.int32)
    return hashlib.blake2b(arr.tobytes(), digest_size=16).hexdigest()


# `PrefixKVPool` / `PrefixPoolEntry` (the node-level pool container both
# backends share) live in repro.core.runtime next to the eviction rule and
# are re-exported above: engine code keeps importing them from here, where
# the device-row lifecycle (materialize / fold / invalidate) is implemented.
