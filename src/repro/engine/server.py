"""Serving loop over REAL JAX replicas, driven by the same `repro.core`
schedulers — and now the same `repro.core.runtime.Runtime` contract — as the
cluster simulator.

Replica compute is executed for real (measured wall time advances per-node
logical clocks); KV transfers physically copy cache slots between replica
buffers and charge modeled link latency. Tool-call delays advance logical
time only. The result: scheduler policies are exercised against a real
engine — prefix reuse, slot pinning, one-shot transfer and occupancy
accounting all have to actually work — while a full trace replays in
seconds on CPU.

Prefill stages dispatch through the replica's AOT-compiled donated bucket
programs by default (`ReplicaEngine.prefill_mode="jit"`: one dispatch per
(append-)prefill, in-slot KV scatter, compile time off the logical clock);
`EngineServer(prefill_mode="reference")` replays the eager per-op oracle
on every replica for parity runs.

Serving is organized as queue-fed stages over an explicit per-conversation
state machine (`ServeSession`): arrival no longer runs prefill inline —
every slot-holding stage (turn-1 prefill, the one-shot KV binding, remote
turns) first passes ADMISSION on its target node. When the node has no free
KV slot the work parks in that node's admission queue (session -> QUEUED,
`NodeState.queued_conversations` observable) and is re-offered when a
conversation ends and frees its slot — backpressure instead of the old
`"no free KV slots"` crash, with `Scheduler.reoffer_admission` as the
optional policy hook.

The decode tail runs as a CONTINUOUS ROTATION over each node's KV slots
(`rotation=True`, the default): every `_iterate` call is one chunk cut.
At the cut the loop first merges READY turns — completed prefills and
post-tool next-turns of conversations already pinned to the node — into
the batch, then re-offers the node's admission queue (so parked sessions
leave QUEUED mid-tail, at the cut where a slot actually freed, ordered by
`Scheduler.select_refill`, default FIFO). Chunks are sized adaptively:
with refill supply observed waiting (admission-queue depth, staged ready
turns) the chunk is cut at the earliest in-flight finish horizon
(bucket-floored min(remaining) — every lane stays live to the cut, zero
masked forwards, and the freed slot turns around immediately); with no
supply the chunk runs to bucket-floored max(remaining) exactly as before
(raggedness absorbs the stagger; cutting early would only buy dispatch
overhead). `rotation=False` preserves the chunk-boundary-only admission
behavior (refills ride the event heap and join one full chunk late) as
the measurable baseline. Either way the scan itself is byte-for-byte the
ragged donated-KV contract documented in ROADMAP "Serving runtime", and
per-(cid, turn) token streams are identical across rotation on/off and
any refill ordering.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.conversation import Conversation, TurnView, view_of
from repro.core.events import (EV_NODE_FAILURE, EV_RECOVERY, EV_TOKENS,
                               EV_TURN_FINISH)
from repro.core.metrics import ConversationRecord, TurnRecord
from repro.core.runtime import (Admission, AdmissionQueue,
                                ConversationJournal, DECODING, DONE,
                                PREFILLING, QUEUED, Runtime, ServeSession,
                                TOOL_WAIT, TRANSFERRING)
from repro.core.scheduler import Scheduler
from repro.core.signals import (NODE_ACTIVE, ClusterView, NodeState,
                                PrefillLatencyCurve)

from .kvcache import prefix_hash
from .replica import DECODE_CHUNKS, ReplicaEngine, decode_chunk_floor


@dataclasses.dataclass
class _TurnTask:
    conv: Conversation
    turn_idx: int
    slot: int
    remaining: int
    next_token: int
    first_token_t: Optional[float] = None
    arrival_t: float = 0.0
    # every sampled token of this turn so far ([prefill argmax] + decoded),
    # journaled at turn completion — the engine's failure-recovery transcript
    stream: List[int] = dataclasses.field(default_factory=list)
    # recovery generation of the conversation when this task was built:
    # finish events carrying a stale generation are dropped (the turn was
    # rewound and is being replayed)
    gen: int = 0


class EngineServer(Runtime):
    def __init__(self, scheduler: Scheduler, replicas: List[ReplicaEngine],
                 link_bw_bytes_s: float = 25e9, seed: int = 0,
                 max_decode_chunk: int = 32, decode_mode: str = "fused",
                 record_tokens: bool = False, strict_accounting: bool = False,
                 rotation: bool = True, rotation_min_chunk: int = 16,
                 prefill_mode: Optional[str] = None,
                 tool_deadline_s: Optional[float] = None,
                 tool_timeout_action: str = "evict",
                 max_transfer_retries: int = 3,
                 transfer_retry_backoff_s: float = 0.01,
                 quarantine_k: Optional[float] = None,
                 quarantine_window: int = 3,
                 quarantine_rejoin_k: Optional[float] = None):
        """decode_mode: "fused" runs up to `max_decode_chunk` tokens per
        dispatch through the donated in-place RAGGED scan (`decode_steps`):
        each slot consumes only its own per-slot share, and turns that
        exhaust their output mid-chunk finish at interpolated timestamps.
        "reference" replays the pre-fusion one-dispatch-per-token path
        (kept for parity tests and before/after benchmarks).
        rotation: True (default) runs the decode tail as a continuous
        rotation — adaptive chunk cuts at observed finish horizons, ready
        turns and parked admissions refilled INTO the batch at every cut
        (see the module docstring). False preserves the chunk-boundary-only
        admission behavior as the comparison baseline; token streams are
        identical either way.
        rotation_min_chunk: shortest chunk (in scan steps) a refill cut may
        produce while longer work remains in the batch — a lane that
        finishes below it freezes briefly instead of forcing a cut, so
        per-dispatch overhead stays amortized (each dispatch costs a few
        scan steps' time; cutting at every tiny finish horizon re-creates
        the retired min-collapse pathology). Tune to the measured
        dispatch-overhead/step-cost ratio of the deployment; the default 16
        suits this container (CPU dispatch ~3-4 scan steps' worth). Chunk
        SIZING never changes token content — only when work runs.
        record_tokens: keep every sampled token per (cid, turn) in
        `sampled_tokens` — O(total output tokens) memory, tests only.
        strict_accounting: at every conversation end, assert the NodeState
        observables (active_kv_tokens, used_slots, queued_prefill_tokens)
        still mirror the KV caches' / admission queues' ground truth on
        every replica — drift detection for tests.
        prefill_mode: None (default) leaves each replica's own mode in
        place; "jit" / "reference" overrides every replica — "reference"
        replays the eager per-op (append-)prefill path as the parity
        oracle (see ReplicaEngine.prefill_mode).
        tool_deadline_s: TOOL_WAIT watchdog (off by default, None). A
        session whose tool call has not returned `tool_deadline_s` seconds
        after entering TOOL_WAIT is acted on per `tool_timeout_action`:
        "evict" frees its KV slot for waiting work (the tool return
        re-admits by journaled replay through the arrival admission path);
        "fail" raises loudly naming the conversation. Either way nothing
        parks forever on a tool that never comes back.
        max_transfer_retries / transfer_retry_backoff_s: bound on one-shot
        KV-transfer attempts per binding (see `inject_transfer_faults`);
        each failed attempt backs off exponentially from the base and
        re-asks `Scheduler.bind_decoder` for a (possibly different)
        decoder. Exhausting the bound raises loudly.
        quarantine_k / quarantine_window / quarantine_rejoin_k: the
        observed-straggler quarantine trigger (Runtime contract; None
        disables it). A replica whose observed_tbt_ema_s exceeds
        quarantine_k × the fleet median for quarantine_window consecutive
        decode chunks leaves the schedulable set (lifecycle QUARANTINED),
        and requalifies once it falls back below quarantine_rejoin_k ×
        median (defaults to quarantine_k) for the same window."""
        assert decode_mode in ("fused", "reference")
        assert prefill_mode in (None, "jit", "reference")
        assert tool_timeout_action in ("evict", "fail")
        if prefill_mode is not None:
            for r in replicas:
                r.prefill_mode = prefill_mode
        self.sched = scheduler
        self.replicas = {r.replica_id: r for r in replicas}
        self.link_bw = link_bw_bytes_s
        # compiled scan buckets top out at DECODE_CHUNKS[-1]; a larger chunk
        # would silently desync server token accounting from the replica
        self.max_decode_chunk = max(1, min(int(max_decode_chunk),
                                           DECODE_CHUNKS[-1]))
        self.decode_mode = decode_mode
        self.record_tokens = record_tokens
        self.strict_accounting = strict_accounting
        self.rotation = rotation
        self.rotation_min_chunk = max(1, min(int(rotation_min_chunk),
                                             self.max_decode_chunk))
        self.seed = seed
        states = {}
        for r in replicas:
            states[r.replica_id] = NodeState(
                node_id=r.replica_id,
                role="prefill" if r.role == "prefill" else (
                    "mixed" if r.role == "mixed" else "decode"),
                kv_capacity_tokens=r.kv.n_slots * r.kv.max_ctx,
                slot_capacity=r.kv.n_slots)
        # observable curve: coarse profile of the actual replica
        curve = PrefillLatencyCurve(0.0, 1e-5, 0.01)
        self.view = ClusterView(states, curve)
        self.states = states
        self.clock: Dict[int, float] = {r.replica_id: 0.0 for r in replicas}
        self.records: Dict[int, ConversationRecord] = {}
        self.sessions: Dict[int, ServeSession] = {}
        self._admission: Dict[int, AdmissionQueue] = {
            r.replica_id: AdmissionQueue(r.replica_id) for r in replicas}
        self._tokens: Dict[Tuple[int, int], np.ndarray] = {}
        # shared-preamble token blocks, keyed (preamble_id, length)
        self._preambles: Dict[Tuple[int, int], np.ndarray] = {}
        self._slots: Dict[int, Tuple[int, int]] = {}  # cid -> (node, slot)
        self._decode_q: Dict[int, List[_TurnTask]] = {
            r.replica_id: [] for r in replicas}
        # rotation staging: ready turns (prefill done) waiting to merge
        # into the node's batch at the next chunk cut, as (ready_t, seq,
        # task) — seq keeps merge order deterministic at equal timestamps
        self._ready: Dict[int, List[Tuple[float, int, _TurnTask]]] = {
            r.replica_id: [] for r in replicas}
        # logical time of the pending _iterate event per node (None = no
        # cut scheduled); lets refills kick an idle rotation awake without
        # flooding the heap with duplicate cut events
        self._iter_at: Dict[int, Optional[float]] = {
            r.replica_id: None for r in replicas}
        self._events: List[Tuple[float, int, object]] = []
        self._seq = itertools.count()
        self._now = 0.0
        self.transfer_bytes = 0.0
        self.n_transfers = 0
        # ----- failure contract state -----
        self.tool_deadline_s = tool_deadline_s
        self.tool_timeout_action = tool_timeout_action
        self.max_transfer_retries = int(max_transfer_retries)
        self.transfer_retry_backoff_s = float(transfer_retry_backoff_s)
        self.journal = ConversationJournal()
        self._convs: Dict[int, Conversation] = {}
        # recovery generation per cid: bumped at every rewind so in-flight
        # finish events from before the failure are recognizably stale
        self._gen: Dict[int, int] = {}
        # arrival_t of each conversation's CURRENT in-flight turn (lets a
        # failure rewind keep the turn's original TTFT reference point)
        self._turn_arrival: Dict[int, float] = {}
        # recovery trigger time per cid (failure, or tool return to a dead/
        # evicted binding) — closed into recovery_latency_s at re-bind
        self._recover_t0: Dict[int, float] = {}
        self._bind_attempts: Dict[int, int] = {}
        self._transfer_fault_budget = 0
        self.n_transfer_retries = 0
        self.n_tool_evictions = 0
        self.n_recoveries = 0
        # ----- replica lifecycle state -----
        self.quarantine_k = quarantine_k
        self.quarantine_window = int(quarantine_window)
        self.quarantine_rejoin_k = quarantine_rejoin_k
        # injected slowdown factor per replica (1.0 = healthy); stretches
        # every measured dt on the logical clock — see inject_slowdown
        self._slow: Dict[int, float] = {}
        # incarnation counter per replica: bumped at every revival so
        # fail -> recover -> fail cycles are distinguishable observations
        self._node_gen: Dict[int, int] = {
            r.replica_id: 0 for r in replicas}
        self.log: List[str] = []
        # sampled token stream per (cid, turn_idx) when record_tokens is
        # set — first token from the turn's prefill, then every decoded
        # token in order (lets tests assert end-to-end token equality
        # across decode modes)
        self.sampled_tokens: Dict[Tuple[int, int], List[int]] = {}

    # ----- helpers ---------------------------------------------------------------
    def _preamble_token_block(self, preamble_id: int, n: int) -> np.ndarray:
        """Deterministic shared-preamble token content: keyed per
        (preamble_id, length), NOT per cid, so every conversation declaring
        the same preamble gets byte-identical prefix bytes — which is what
        makes `prefix_hash` actually collide across them (the pool keys on
        token content, never on the trace-level id)."""
        key = (int(preamble_id), int(n))
        if key not in self._preambles:
            vocab = next(iter(self.replicas.values())).cfg.vocab_size
            rng = np.random.RandomState(
                (self.seed * 1000003 + 0x5eed + preamble_id * 104729)
                % (2 ** 31))
            self._preambles[key] = rng.randint(
                0, vocab, size=n).astype(np.int32)
        return self._preambles[key]

    def _turn_tokens(self, conv: Conversation, idx: int) -> np.ndarray:
        # keyed per (cid, turn) so token content is independent of the ORDER
        # turns are first reached — decode chunking / scheduling / ADMISSION
        # changes may reorder events, and token streams must stay comparable
        # across runs
        key = (conv.cid, idx)
        if key not in self._tokens:
            vocab = next(iter(self.replicas.values())).cfg.vocab_size
            rng = np.random.RandomState(
                (self.seed * 1000003 + conv.cid * 9973 + idx * 7919)
                % (2 ** 31))
            toks = rng.randint(
                0, vocab, size=conv.turns[idx].append_tokens).astype(np.int32)
            if (idx == 0 and conv.preamble_id is not None
                    and conv.preamble_tokens > 0):
                # turn 1 opens with the shared preamble; only the tail past
                # it is per-conversation content
                toks[:conv.preamble_tokens] = self._preamble_token_block(
                    conv.preamble_id, conv.preamble_tokens)
            self._tokens[key] = toks
        return self._tokens[key]

    def _prefix_split(self, conv: Conversation, node: ReplicaEngine) -> int:
        """The prefix length turn 1 splits at on `node` (0 = no split): the
        declared preamble, EXCEPT for frontend models, whose prefill
        prepends non-token positions the split cannot express — there
        neither the pool nor the split applies, consistently, so streams
        stay comparable pool-on vs pool-off."""
        if conv.preamble_tokens <= 0 or node.cfg.frontend != "none":
            return 0
        return conv.preamble_tokens

    def _pool_probe(self, node_id: int, conv: Conversation) -> Optional[int]:
        """OBSERVED pool state at offer time: returns the delta-token
        prefill-compute charge when `node_id`'s pool currently holds this
        conversation's preamble rows (side-effect-free `contains` — the hit
        counter records only reads that feed a prefill), else None (charge
        the full first turn). The charge is fixed at offer time; if the
        entry is evicted before the prefill runs, the recompute is honest
        extra work, not a new backlog charge — the counter stays an
        observation of what was known when the work was accepted."""
        node = self.replicas[node_id]
        p = self._prefix_split(conv, node)
        if p <= 0 or node.prefix_pool is None:
            return None
        key = prefix_hash(self._turn_tokens(conv, 0)[:p])
        if node.prefix_pool.contains(key):
            return conv.first_input_len - p
        return None

    def _sync_pool_state(self, node_id: int):
        """Mirror the replica's prefix-pool ground truth into the NodeState
        observables (strict accounting asserts exactly this equality)."""
        pool = self.replicas[node_id].prefix_pool
        if pool is None:
            return
        st = self.states[node_id]
        st.pooled_prefix_tokens = pool.pooled_tokens
        st.pooled_prefix_entries = pool.n_entries
        st.pooled_prefix_hits = pool.total_hits
        st.pooled_prefix_evictions = pool.n_evictions

    def _push(self, t: float, fn):
        heapq.heappush(self._events, (t, next(self._seq), fn))

    def call_at(self, t: float, fn) -> "EngineServer":
        """Schedule `fn()` on the event heap at logical time `t` — the hook
        chaos drivers arm time-scheduled faults through."""
        self._push(max(t, self._now), fn)
        return self

    @property
    def now_s(self) -> float:
        return self._now

    def _stretched(self, node_id: int, dt: float) -> float:
        """Apply any injected slowdown to a measured compute time before it
        advances the logical clock (and hence the observed TBT EMA). Token
        content never changes — a straggler is slow, not wrong."""
        return dt * self._slow.get(node_id, 1.0)

    # ----- Runtime protocol --------------------------------------------------------
    def submit(self, convs: List[Conversation]) -> "EngineServer":
        self._assert_accepting()
        for c in convs:
            self._convs[c.cid] = c
            self.records[c.cid] = ConversationRecord(c.cid, c.arrival_s)
            self._make_session(c.cid, c.arrival_s)
            # staged arrival injection: a submission landing after logical
            # time passed its arrival stamp executes at now (the logical
            # clock must never run backwards); the session keeps the trace's
            # arrival_s, so the gap is measured as queue wait, not erased
            self._push(max(c.arrival_s, self._now),
                       lambda c=c: self._arrive(c))
        return self

    def run(self) -> "EngineServer":
        self.run_pending()
        self.close()
        return self

    def run_pending(self, max_events: Optional[int] = None) -> int:
        n = 0
        while self._events and (max_events is None or n < max_events):
            t, _, fn = heapq.heappop(self._events)
            self._now = t
            fn()
            n += 1
        return n

    def results(self) -> List[ConversationRecord]:
        return [r for r in self.records.values() if r.turns]

    def serve(self, convs: List[Conversation]) -> List[ConversationRecord]:
        return self.submit(convs).run().results()

    def _can_admit(self, node_id: int, adm: Admission) -> bool:
        """Ground truth: a free KV slot on the replica. A slot is a fixed
        max_ctx region, so a free slot IS the headroom guarantee — except
        for work that can never fit, which must fail loudly, not queue
        forever."""
        node = self.replicas[node_id]
        if self._never_fits(node_id, adm):
            # mirror SlotKVCache.acquire()'s message style: name the
            # conversation, the node, and the slot headroom it could never
            # fit into — a refill candidate that cannot EVER fit must fail
            # loudly at offer time, not rot in the queue
            raise RuntimeError(
                f"conversation {adm.cid} can never fit on replica "
                f"{node_id}: needs {adm.need_tokens} KV tokens but every "
                f"slot holds max_ctx={node.kv.max_ctx} "
                f"({int(node.kv.active.sum())}/{node.kv.n_slots} slots "
                f"active, {node.kv.active_kv_tokens} live KV tokens); no "
                f"amount of queueing or refill can admit it")
        return bool((~node.kv.active).any())

    def _never_fits(self, node_id: int, adm: Admission) -> bool:
        return adm.need_tokens > self.replicas[node_id].kv.max_ctx

    def check_accounting(self):
        """Assert every NodeState observable mirrors its replica's KV ground
        truth (satellite of the runtime redesign: observation means the
        counters must BE the state, not an estimate of it). The prefill
        backlog counter is included: at every event boundary a node's
        `queued_prefill_tokens` must equal exactly the first-turn tokens of
        the arrivals PARKED in its admission queue (admitted turn-1
        prefills run synchronously, so nothing is admitted-unstarted when
        this runs) — the counter must follow a re-placed arrival to the
        queue that actually holds it, not to where it eventually runs."""
        for nid, node in self.replicas.items():
            st = self.states[nid]
            assert st.active_kv_tokens == node.kv.active_kv_tokens, (
                f"replica {nid}: NodeState.active_kv_tokens="
                f"{st.active_kv_tokens} != kv ground truth "
                f"{node.kv.active_kv_tokens}")
            assert st.used_slots == int(node.kv.active.sum()), (
                f"replica {nid}: NodeState.used_slots={st.used_slots} != "
                f"{int(node.kv.active.sum())} active KV slots")
            parked = sum(a.charge for a in
                         self._admission[nid].admissions("arrival"))
            assert st.queued_prefill_tokens == parked, (
                f"replica {nid}: NodeState.queued_prefill_tokens="
                f"{st.queued_prefill_tokens} != {parked} prefill-compute "
                f"tokens parked in its admission queue (backlog counter "
                f"drift; charges are delta-tokens for observed pool hits)")
            pool = node.prefix_pool
            if pool is not None:
                assert st.pooled_prefix_tokens == pool.pooled_tokens, (
                    f"replica {nid}: NodeState.pooled_prefix_tokens="
                    f"{st.pooled_prefix_tokens} != pool ground truth "
                    f"{pool.pooled_tokens}")
                assert st.pooled_prefix_entries == pool.n_entries, (
                    f"replica {nid}: NodeState.pooled_prefix_entries="
                    f"{st.pooled_prefix_entries} != {pool.n_entries}")
                assert st.pooled_prefix_hits == pool.total_hits, (
                    f"replica {nid}: NodeState.pooled_prefix_hits="
                    f"{st.pooled_prefix_hits} != {pool.total_hits}")
                assert st.pooled_prefix_evictions == pool.n_evictions, (
                    f"replica {nid}: NodeState.pooled_prefix_evictions="
                    f"{st.pooled_prefix_evictions} != {pool.n_evictions}")

    # ----- arrival & turn-1 prefill -------------------------------------------------
    def _arrive(self, conv: Conversation):
        pl = self.sched.place_first_prefill(view_of(conv), self.view)
        st = self.states[pl.node_id]
        # backlog observable covers parked + admitted-unstarted prefill
        # work. With an OBSERVED pool hit on the placed node, only the
        # delta past the pooled preamble is prefill COMPUTE — charging the
        # full turn would overstate the backlog `prefill_backlog_s` reads
        # (need_tokens stays the full context: the slot still lands all of
        # it, so the headroom/fit ask is unchanged).
        delta = self._pool_probe(pl.node_id, conv)
        charge = conv.first_input_len if delta is None else delta
        st.queued_prefill_tokens += charge
        self._offer(pl.node_id,
                    Admission(conv.cid, conv.first_input_len,
                              lambda nid, conv=conv, charge=charge:
                              self._prefill_turn1(conv, nid, charge),
                              kind="arrival",
                              charge_tokens=None if delta is None else delta),
                    self._now)

    def _on_reoffer_move(self, adm: Admission, from_node: int, to_node: int):
        """A reoffer policy moved a parked admission: the prefill backlog
        observable follows the ARRIVAL to the queue that now holds it, at
        the instant it moves. (It used to follow only when the prefill
        finally RAN, so a twice-parked arrival left the counter sitting on
        the first node for the whole parked interval — the backlog drift
        strict accounting now rejects.)"""
        if adm.kind == "arrival":
            self.states[from_node].queued_prefill_tokens -= adm.charge
            self.states[to_node].queued_prefill_tokens += adm.charge

    def _prefill_turn1(self, conv: Conversation, node_id: int,
                       charge: Optional[int] = None):
        node = self.replicas[node_id]
        st = self.states[node_id]
        start = max(self._now, self.clock[node_id])
        self.sessions[conv.cid].transition(PREFILLING, start)

        # run the real prefill; a declared preamble ALWAYS splits turn 1 at
        # its boundary (the split, not the pool, fixes the math — streams
        # stay byte-identical pool-on vs pool-off)
        slot = node.kv.acquire()
        st.used_slots += 1
        tokens = self._turn_tokens(conv, 0)
        fe = None
        if node.cfg.frontend != "none":
            fe = jnp.zeros((1, node.cfg.frontend_len or node.cfg.encoder_seq,
                            node.cfg.d_model), node.cfg.jnp_dtype)
        next_tok, dt = node.prefill_conversation(
            slot, tokens, fe, prefix_len=self._prefix_split(conv, node))
        dt = self._stretched(node_id, dt)
        self._sync_pool_state(node_id)
        done_t = start + dt
        self.clock[node_id] = done_t
        st.queued_prefill_tokens -= (conv.first_input_len if charge is None
                                     else charge)
        # mirror the slot's WRITTEN length (includes frontend positions),
        # not the nominal input length — the two drift for frontend models
        written = int(node.kv.lengths[slot])
        st.active_kv_tokens += written

        if node.role in ("decode", "mixed"):
            # collocated: stay put
            self._bind_done(conv, node_id, slot, int(next_tok), done_t)
            return
        # disaggregated: bind decoder + one-shot transfer. The prefiller's
        # slot frees NOW (the package travels host-side); the binding itself
        # must pass admission on the decoder.
        bind = self.sched.bind_decoder(view_of(conv), self.view)
        pkg = node.kv.export_slot(slot)
        node.kv.release(slot)
        st.used_slots -= 1
        st.active_kv_tokens -= written
        self._pump(node_id, self._now)
        # if the decoder is full, the binding parks at its prefill-completion
        # time (done_t): that is when the package became ready to move
        self._offer(bind.node_id,
                    Admission(conv.cid, pkg["length"],
                              lambda nid, conv=conv, pkg=pkg,
                              nt=int(next_tok), done_t=done_t:
                              self._transfer_bind(conv, nid, pkg, nt,
                                                  max(done_t, self._now))),
                    done_t)

    def _transfer_bind(self, conv: Conversation, node_id: int, pkg,
                       next_tok: int, t: float, turn_idx: int = 0,
                       arrival_t: Optional[float] = None):
        """One-shot KV transfer onto the admitted decoder (t = when the
        package starts moving: prefill completion, or the later admission;
        turn_idx > 0 when the binding resumes a failure-recovered turn).
        An armed transfer fault (`inject_transfer_faults`) kills the attempt
        before any KV lands; the binding retries with exponential backoff on
        a decoder the scheduler chooses fresh, bounded by
        `max_transfer_retries` — then fails loudly."""
        dec = self.replicas[node_id]
        st = self.states[node_id]
        self.sessions[conv.cid].transition(TRANSFERRING, t)
        if self._transfer_fault_budget > 0:
            self._transfer_fault_budget -= 1
            self.n_transfer_retries += 1
            attempt = self._bind_attempts.get(conv.cid, 0) + 1
            self._bind_attempts[conv.cid] = attempt
            if attempt > self.max_transfer_retries:
                raise RuntimeError(
                    f"KV transfer for conversation {conv.cid} failed on "
                    f"{attempt} consecutive attempts "
                    f"(max_transfer_retries={self.max_transfer_retries}); "
                    f"giving up loudly")
            backoff = self.transfer_retry_backoff_s * (2 ** (attempt - 1))
            self.log.append(
                f"t={t:.3f} KV transfer to replica {node_id} FAILED for "
                f"cid {conv.cid} (attempt {attempt}); retrying in "
                f"{backoff:.3f}s")

            def retry(conv=conv, pkg=pkg, nt=next_tok, idx=turn_idx,
                      at=arrival_t):
                # re-ask the scheduler at RETRY time: the view may have
                # changed (the faulty target may be gone or full)
                pl = self.sched.bind_decoder(view_of(conv), self.view)
                self._offer(pl.node_id,
                            Admission(conv.cid, pkg["length"],
                                      lambda nid: self._transfer_bind(
                                          conv, nid, pkg, nt,
                                          max(t + backoff, self._now),
                                          turn_idx=idx, arrival_t=at)),
                            self._now)

            self._push(t + backoff, retry)
            return
        self._bind_attempts.pop(conv.cid, None)
        dslot = dec.kv.acquire()
        st.used_slots += 1
        dec.kv.import_slot(dslot, pkg)
        st.active_kv_tokens += pkg["length"]
        nbytes = dec.kv.nbytes_of(pkg)
        self.transfer_bytes += nbytes
        self.n_transfers += 1
        self.records[conv.cid].n_kv_transfers += 1
        xfer_t = nbytes / self.link_bw + 0.005
        self._bind_done(conv, node_id, dslot, next_tok, t + xfer_t,
                        turn_idx=turn_idx, arrival_t=arrival_t)

    def _bind_done(self, conv, node_id, slot, next_tok, t, turn_idx: int = 0,
                   arrival_t: Optional[float] = None):
        self._slots[conv.cid] = (node_id, slot)
        self.sessions[conv.cid].node_id = node_id
        st = self.states[node_id]
        st.active_conversations += 1
        t0 = self._recover_t0.pop(conv.cid, None)
        if t0 is not None:
            # recovery closed: trigger -> interrupted turn's decode runnable
            self.records[conv.cid].recovery_latency_s.append(t - t0)
        self._begin_decode(conv, turn_idx, next_tok, t, arrival_t=arrival_t)

    # ----- decode ---------------------------------------------------------------------
    def _begin_decode(self, conv, turn_idx, next_tok, ready_t,
                      arrival_t=None):
        """A turn's prefill completed at logical time `ready_t`: hand it to
        the bound node's decode rotation (`arrival_t`, default ready_t, is
        when the turn became RUNNABLE — tool returned / conversation
        arrived — and feeds its TTFT). Under rotation the task STAGES
        immediately (host-side) and merges into the batch at the first
        chunk cut whose start covers ready_t — no event-heap round trip, so
        a refill never misses the next chunk. With rotation off it rides
        the event heap exactly as before: the task lands in the queue when
        its event fires and joins at the following chunk boundary (the
        chunk-boundary-only admission baseline)."""
        node_id, slot = self._slots[conv.cid]
        sess = self.sessions[conv.cid]
        sess.turn_idx = turn_idx
        sess.transition(DECODING, ready_t)
        task = _TurnTask(conv=conv, turn_idx=turn_idx, slot=slot,
                         remaining=conv.turns[turn_idx].output_tokens,
                         next_token=next_tok,
                         arrival_t=ready_t if arrival_t is None else arrival_t,
                         stream=[next_tok],
                         gen=self._gen.get(conv.cid, 0))
        self._turn_arrival[conv.cid] = task.arrival_t
        if self.record_tokens:
            # alias the task's live stream: a failure rewind rebuilds the
            # task, so the dict always points at the CURRENT attempt's tokens
            self.sampled_tokens[(conv.cid, turn_idx)] = task.stream
        # the turn's opening token (the prefill argmax, stream[0]) exists
        # the moment the task stages — publish it from here so subscribers
        # concatenating `tokens` payloads reproduce task.stream exactly
        self._publish(EV_TOKENS, ready_t, cid=conv.cid, turn_idx=turn_idx,
                      node_id=node_id, tokens=[next_tok], per_token_s=0.0)
        if self.rotation:
            self._ready[node_id].append((ready_t, next(self._seq), task))
            self._kick(node_id, ready_t)
        else:
            self._push(ready_t, lambda: self._enqueue_task(node_id, task))

    def _enqueue_task(self, node_id: int, task: _TurnTask):
        """Legacy (rotation=False) join: at the event time, append to the
        decode queue; the task is batched from the next chunk boundary on."""
        q = self._decode_q[node_id]
        q.append(task)
        if len(q) == 1:
            self._push(max(self._now, self.clock[node_id]),
                       lambda: self._iterate(node_id))

    def _kick(self, node_id: int, t: float):
        """Schedule a chunk cut at logical time >= t unless one is already
        pending no later than t (duplicate cut events are harmless — the
        clock serializes chunks — but pointless)."""
        t = max(t, self._now)
        at = self._iter_at[node_id]
        if at is not None and at <= t:
            return
        self._iter_at[node_id] = t
        self._push(t, lambda: self._iterate(node_id))

    def _merge_ready(self, node_id: int, start: float):
        """Refill supply #1: merge staged ready turns (completed prefills /
        post-tool next-turns of conversations pinned here) whose ready time
        is covered by the chunk start, in (ready_t, seq) order."""
        staged = self._ready[node_id]
        if not staged:
            return
        staged.sort()
        join = [s for s in staged if s[0] <= start]
        if not join:
            return
        self._ready[node_id] = staged[len(join):]
        self._decode_q[node_id].extend(task for _, _, task in join)

    def _refill_supply(self, node_id: int) -> bool:
        """Observed refill supply at a chunk cut: conversations parked in
        this node's admission queue, or staged ready turns not yet coverable
        by the chunk start (e.g. an in-flight remote-turn return). Both are
        state the runtime already owns — queue depth and staged work are
        observations; nothing predicts WHEN a tool returns."""
        return (self.states[node_id].queued_conversations > 0
                or bool(self._ready[node_id]))

    def _iterate(self, node_id: int):
        node = self.replicas[node_id]
        if not self.states[node_id].alive:
            return  # stale chunk-cut event for a replica that since died
        if self.rotation:
            # one chunk cut: refill the batch from both supplies before
            # sizing the chunk. Suppress re-kicks while cutting — staging
            # during the merge below must not spawn duplicate cut events.
            self._iter_at[node_id] = self._now
            start = max(self._now, self.clock[node_id])
            self._merge_ready(node_id, start)          # supply 1: ready turns
            if len(self._admission[node_id]):
                # supply 2: parked admissions — sessions leave QUEUED at
                # the cut (mid-tail), ordered by Scheduler.select_refill;
                # an admitted arrival prefills inline (advancing the node
                # clock) and stages, so the second merge batches it.
                # Pumped even with every slot busy: reoffer policies are
                # entitled to drain a still-full node's queue toward idle
                # peers at every cut (the default FIFO breaks immediately)
                self._pump(node_id, self._now)
                start = max(start, self.clock[node_id])
                self._merge_ready(node_id, start)
            q = self._decode_q[node_id]
            if not q:
                self._iter_at[node_id] = None
                staged = self._ready[node_id]
                if staged:  # future-ready work only: cut again when it lands
                    self._kick(node_id, min(s[0] for s in staged))
                return
            start = max(start, self.clock[node_id])
        else:
            q = self._decode_q[node_id]
            if not q:
                return
        n_slots = node.kv.n_slots
        next_tokens = np.zeros(n_slots, np.int32)
        emit = np.zeros(n_slots, bool)
        rem = np.zeros(n_slots, np.int32)
        for task in q:
            s = task.slot
            next_tokens[s] = task.next_token
            emit[s] = True
            # per-slot room: each slot's chunk share is clamped to ITS OWN
            # headroom — one long-context neighbor no longer shrinks (or
            # falsely trips) the whole batch's chunk
            room = node.kv.max_ctx - int(node.kv.lengths[s])
            if room <= 0:
                # a silent overflow would drop the scattered KV write while
                # host lengths keep advancing — fail loudly in BOTH modes
                raise RuntimeError(
                    f"KV slot overflow on replica {node_id}: slot {s} "
                    f"(cid {task.conv.cid}) is at max_ctx={node.kv.max_ctx} "
                    f"with {task.remaining} output tokens remaining")
            # floor 1 covers zero-output turns — pre-PR decoded one there
            rem[s] = max(1, min(task.remaining, self.max_decode_chunk, room))
        if not self.rotation:
            start = max(self._now, self.clock[node_id])

        if self.decode_mode == "reference":
            n = 1
            rem = np.minimum(rem, 1)
            sampled, dt = node.decode_step_all_reference(next_tokens, emit)
            seq = sampled[None]
        else:
            if self.rotation and self._refill_supply(node_id):
                # rotation under pressure: cut at the earliest OBSERVED
                # in-flight finish horizon (bucket-floored min(remaining)),
                # floored at rotation_min_chunk so per-dispatch overhead
                # stays amortized — a lane finishing below the floor
                # freezes for at most (floor - remaining) steps, and the
                # freed slot turns around into waiting work at the cut
                # instead of idling to the batch's longest tail
                lo, hi = int(rem[emit].min()), int(rem[emit].max())
                n = decode_chunk_floor(
                    max(lo, min(hi, self.rotation_min_chunk)))
            else:
                # no refill supply (or rotation off): ragged chunk sized
                # from the LONGEST remaining task — a nearly-finished slot
                # freezes mid-scan while its neighbors run on; cutting
                # early here would only buy dispatch overhead, since no
                # waiting work could use the freed lane
                n = decode_chunk_floor(int(rem[emit].max()))
            rem = np.minimum(rem, n)
            seq, dt = node.decode_steps(next_tokens, emit, rem)
        dt = self._stretched(node_id, dt)
        t_done = start + dt
        per_tok = dt / n
        self.clock[node_id] = t_done
        st = self.states[node_id]
        # rotation observables: lane-step counters of the dispatch that just
        # ran (scan computes every slot in lockstep for n steps; an emitting
        # slot is live for its own rem share, a masked no-op after)
        st.decode_scan_steps += n
        st.decode_lane_steps_emitting += n * int(emit.sum())
        st.decode_lane_steps_live += int(rem[emit].sum())
        ema = st.observed_tbt_ema_s
        st.observed_tbt_ema_s = 0.9 * ema + 0.1 * per_tok if ema else per_tok
        # one observed decode chunk: advance the straggler-quarantine
        # machine on the EMA that just updated (shared Runtime trigger)
        self._observe_chunk_tbt(node_id, t_done)

        for task in q:
            slot = task.slot
            took = int(rem[slot])
            if task.first_token_t is None:
                # per-token timestamps interpolate the measured chunk time
                task.first_token_t = start + per_tok
            task.remaining -= took
            task.next_token = int(seq[took - 1, slot])
            new_toks = [int(t) for t in seq[:took, slot]]
            task.stream.extend(new_toks)
            # per-token emission out of the chunk that just ran: the tokens
            # and their interpolated timestamps are the same values the
            # stream/finish bookkeeping above already owns
            self._publish(EV_TOKENS, start + per_tok, cid=task.conv.cid,
                          turn_idx=task.turn_idx, node_id=node_id,
                          tokens=new_toks, per_token_s=per_tok)
            st.active_kv_tokens += took
            if task.remaining <= 0:
                # mid-chunk finish: this turn's last token landed at step
                # `took`, not at the chunk boundary — emit the finish event
                # at its interpolated timestamp so tool time (and the next
                # turn's prefill) starts there instead of waiting for the
                # batch's longest slot
                t_fin = start + took * per_tok
                self._push(t_fin, lambda task=task, t=t_fin:
                           self._finish_turn(task, t))
        # rebuild the queue once per iteration (not O(n) removes per finish)
        self._decode_q[node_id] = q = [t for t in q if t.remaining > 0]
        if self.rotation:
            # schedule the next cut; finish events above land first (their
            # interpolated times are <= t_done), so releases pump the
            # admission queue and post-tool turns stage before the cut
            self._iter_at[node_id] = None
            if q or self._ready[node_id]:
                self._kick(node_id, t_done)
        elif q:
            # chunk-boundary baseline: newly-ready turns join at the NEXT
            # boundary after their event lands
            self._push(t_done, lambda: self._iterate(node_id))

    def _finish_turn(self, task: _TurnTask, t: float):
        conv, idx = task.conv, task.turn_idx
        if task.gen != self._gen.get(conv.cid, 0):
            # finish event from before a failure rewound this conversation:
            # the turn's partial output was discarded and is being replayed
            # (the replayed finish will land with the current generation)
            return
        turn = conv.turns[idx]
        sess = self.sessions[conv.cid]
        self.journal.record(conv.cid, idx, task.stream)
        self._publish(EV_TURN_FINISH, t, cid=conv.cid, turn_idx=idx,
                      node_id=self._slots[conv.cid][0],
                      n_output_tokens=turn.output_tokens)
        self.records[conv.cid].turns.append(TurnRecord(
            turn_idx=idx, arrival_s=task.arrival_t,
            first_token_s=task.first_token_t, last_token_s=t,
            n_output_tokens=turn.output_tokens))
        if idx + 1 < conv.n_turns:
            sess.transition(TOOL_WAIT, t)
            sess.turn_idx = idx + 1
            ready = t + turn.tool_time_s
            self._push(ready, lambda: self._next_turn(conv, idx + 1, ready))
            if self.tool_deadline_s is not None:
                self._push(t + self.tool_deadline_s,
                           lambda gen=task.gen:
                           self._tool_watchdog(conv, idx + 1, gen,
                                               t + self.tool_deadline_s))
        else:
            sess.transition(DONE, t)
            self.journal.drop(conv.cid)
            self._turn_arrival.pop(conv.cid, None)
            # _gen is kept: a pre-rewind finish event can still be in the
            # heap after DONE, and must keep reading as stale
            node_id, slot = self._slots.pop(conv.cid)
            node = self.replicas[node_id]
            st = self.states[node_id]
            st.active_kv_tokens -= int(node.kv.lengths[slot])
            st.active_conversations -= 1
            node.kv.release(slot)
            st.used_slots -= 1
            self.sched.on_conversation_end(conv.cid, self.view)
            if self.strict_accounting:
                self.check_accounting()
            # occupancy freed: re-offer parked admissions (backpressure)
            self._pump(node_id, self._now)
            # a DRAINING node whose last resident tail just left rejoins
            self._maybe_finish_draining(node_id, self._now)

    # ----- turn 2+ --------------------------------------------------------------------
    def _next_turn(self, conv: Conversation, idx: int, ready_t: float):
        binding = self._slots.get(conv.cid)
        if binding is None or not self.states[binding[0]].alive:
            # the tool returned to a dead binding (replica failed during
            # TOOL_WAIT) or an evicted one (tool-deadline watchdog freed the
            # slot): lazy recovery by journaled replay, mirroring the
            # simulator's _on_turn_arrival. The turn becomes runnable NOW,
            # so its TTFT reference point is ready_t.
            self._recover(conv, idx, ready_t)
            return
        node_id, slot = binding
        node = self.replicas[node_id]
        ctx = int(node.kv.lengths[slot])
        tv = TurnView(cid=conv.cid, turn_idx=idx,
                      append_tokens=conv.turns[idx].append_tokens,
                      context_tokens=ctx)
        pl = self.sched.place_turn(tv, node_id, self.view)
        tokens = self._turn_tokens(conv, idx)
        self.records[conv.cid].n_kv_transfers += int(pl.kv_transfer)

        if pl.node_id == node_id:
            # ConServe fast path: local append-prefill with hot prefix; the
            # slot is already held, so no admission is involved
            start = max(ready_t, self.clock[node_id])
            self.sessions[conv.cid].transition(PREFILLING, start)
            next_tok, dt = node.append_prefill(slot, tokens)
            dt = self._stretched(node_id, dt)
            self.clock[node_id] = start + dt
            self.states[node_id].active_kv_tokens += len(tokens)
            self._begin_decode(conv, idx, int(next_tok), start + dt,
                               arrival_t=ready_t)
            return
        # remote append-prefill needs a temporary slot on the remote node —
        # that acquisition passes admission like every other one
        self.records[conv.cid].n_remote_turns += 1
        self._offer(pl.node_id,
                    Admission(conv.cid, ctx + len(tokens),
                              lambda nid, conv=conv, idx=idx:
                              self._remote_turn(conv, idx, nid,
                                                max(ready_t, self._now)),
                              kind="turn"),
                    self._now)

    def _remote_turn(self, conv: Conversation, idx: int, remote_id: int,
                     ready_t: float):
        """Remote append-prefill: move KV to the remote node, prefill there,
        move back (bidirectional — the per-turn disaggregation penalty)."""
        node_id, slot = self._slots[conv.cid]
        node = self.replicas[node_id]
        remote = self.replicas[remote_id]
        rst = self.states[remote_id]
        tokens = self._turn_tokens(conv, idx)
        self.sessions[conv.cid].transition(TRANSFERRING, ready_t)
        pkg = node.kv.export_slot(slot)
        nbytes = node.kv.nbytes_of(pkg)
        rslot = remote.kv.acquire()
        rst.used_slots += 1
        remote.kv.import_slot(rslot, pkg)
        rst.active_kv_tokens += pkg["length"]
        t0 = max(ready_t, self.clock[remote_id]) + nbytes / self.link_bw
        self.sessions[conv.cid].transition(PREFILLING, t0)
        next_tok, dt = remote.append_prefill(rslot, tokens)
        dt = self._stretched(remote_id, dt)
        # the append landed in the remote slot: mirror it before the release
        # below subtracts the slot's full (grown) length
        rst.active_kv_tokens += len(tokens)
        pkg2 = remote.kv.export_slot(rslot)
        nbytes2 = remote.kv.nbytes_of(pkg2)
        rst.active_kv_tokens -= int(remote.kv.lengths[rslot])
        remote.kv.release(rslot)
        rst.used_slots -= 1
        node.kv.import_slot(slot, pkg2)
        self.transfer_bytes += nbytes + nbytes2
        self.n_transfers += 2
        done = t0 + dt + nbytes2 / self.link_bw
        self.clock[remote_id] = t0 + dt
        self.states[node_id].active_kv_tokens += len(tokens)
        self._pump(remote_id, self._now)
        self._begin_decode(conv, idx, int(next_tok), done, arrival_t=ready_t)

    # ----- failure contract -----------------------------------------------------------
    def fail_replica(self, node_id: int, at_s: float) -> "EngineServer":
        """Schedule replica `node_id` to die at logical time `at_s`. Same
        injection API as ClusterSimulator.inject_failure: every in-flight
        conversation on the dead replica recovers by deterministic journaled
        replay on a healthy one, and parked admissions re-place through the
        same scheduler decision points that placed them."""
        self._push(at_s, lambda: self._fail(node_id))
        return self

    # simulator-API parity, so benchmarks drive both backends uniformly
    inject_failure = fail_replica

    def recover_replica(self, node_id: int, at_s: float) -> "EngineServer":
        """Schedule failed replica `node_id` to REJOIN at logical time
        `at_s`, cold: its slot cache and prefix pool stay invalidated (they
        died with the node), resident counters are zero, cumulative
        counters (hits, evictions, replayed tokens) survive — they count
        events that already happened. The node re-enters
        `ClusterView.nodes()` and every admission queue is pumped so parked
        work can land on the fresh capacity immediately. fail -> recover ->
        fail cycles are legal (per-node generations); recovering a replica
        that is still alive raises."""
        self._push(at_s, lambda: self._recover_node(node_id))
        return self

    # simulator-API parity (mirrors fail_replica / inject_failure)
    revive_node = recover_replica

    def _recover_node(self, node_id: int):
        st = self.states[node_id]
        if st.alive:
            raise RuntimeError(
                f"replica {node_id} is already alive; only a failed "
                f"replica can rejoin")
        st.alive = True
        st.lifecycle = NODE_ACTIVE
        # the EMA observed the PREVIOUS incarnation's chunks; the rejoined
        # replica starts with no observations of its own
        st.observed_tbt_ema_s = 0.0
        self._node_gen[node_id] = self._node_gen.get(node_id, 0) + 1
        # the node's logical clock never ran backwards while dead
        self.clock[node_id] = max(self.clock[node_id], self._now)
        self._rejoin_node(node_id, self._now, reason="from_dead")

    def _node_has_inflight(self, node_id: int) -> bool:
        """In-flight work resident on `node_id`: batched or staged decode
        tasks, plus any session whose KV slot binding names the node
        (TOOL_WAIT sessions hold their slot between turns)."""
        if self._decode_q[node_id] or self._ready[node_id]:
            return True
        return any(nid == node_id for nid, _ in self._slots.values())

    def inject_slowdown(self, node_id: int, factor: float,
                        at_s: Optional[float] = None) -> "EngineServer":
        """Stretch replica `node_id`'s measured compute times by `factor`
        on the logical clock from `at_s` (immediately when None). The
        straggler is SLOW, not wrong: token content is untouched, but every
        dt the server measures — prefill, append-prefill, decode chunks —
        is multiplied before it advances the node clock, so the TBT EMA
        observes the slowdown and the quarantine trigger can act on it.
        factor=1.0 ends the slowdown."""
        def arm():
            self._slow[node_id] = float(factor)
        if at_s is None:
            arm()
        else:
            self._push(at_s, arm)
        return self

    def _fail(self, node_id: int):
        node = self.replicas[node_id]
        st = self.states[node_id]
        if not st.alive:
            raise RuntimeError(f"replica {node_id} failed twice")
        st.alive = False
        self._lifecycle_streaks.pop(node_id, None)
        # find the victims BEFORE tearing state down. Only DECODING sessions
        # need immediate replay (staged ready turns included — their session
        # is already DECODING); TOOL_WAIT sessions hold no runnable work and
        # recover lazily when their tool returns to the dead binding.
        # PREFILLING/TRANSFERRING run synchronously inside one event, so no
        # session can be caught mid-stage at an event boundary.
        victims = []
        for cid, (nid, _slot) in list(self._slots.items()):
            if nid != node_id:
                continue
            sess = self.sessions[cid]
            if sess.state == DECODING:
                victims.append((self._convs[cid], sess.turn_idx,
                                self._turn_arrival.get(cid, self._now)))
            else:
                # a TOOL_WAIT session's binding dies WITH the node: sever it
                # now so a later revival (recover_replica) can't make the
                # stale slot reference look valid again — the tool return
                # finds no binding and recovers by journaled replay exactly
                # as it would against a still-dead node
                self._slots.pop(cid)
                sess.node_id = None
        # the replica's KV is gone at once: invalidate every slot and zero
        # the mirroring observables wholesale (strict accounting keeps
        # checking dead replicas against exactly this ground truth)
        node.kv.invalidate_all()
        if node.prefix_pool is not None:
            # pooled rows die with the node's slot cache: drop them so a
            # recovered conversation re-populates through the normal miss
            # path instead of dangling a reference to dead device buffers
            node.prefix_pool.invalidate_all()
        st.active_kv_tokens = 0
        st.used_slots = 0
        st.active_conversations = 0
        st.reserved_kv_tokens = 0
        # resident pool observables zero with the pool; the cumulative
        # hit/eviction counters survive (events that already happened)
        self._sync_pool_state(node_id)
        self._decode_q[node_id] = []
        self._ready[node_id] = []
        self._iter_at[node_id] = None
        self.log.append(
            f"t={self._now:.3f} replica {node_id} FAILED; replaying "
            f"{len(victims)} in-flight conversations on healthy replicas "
            f"(tool-waiting ones recover lazily)")
        self._publish(EV_NODE_FAILURE, self._now, node_id=node_id,
                      n_victims=len(victims))
        # parked admissions would never be pumped: re-place each through the
        # SAME decision point that placed it (shared Runtime mechanism —
        # raises loudly if no healthy target exists)
        self._drain_dead_node(node_id, self._now)
        for conv, turn_idx, arrival_t in victims:
            self._recover(conv, turn_idx, arrival_t)

    def _recover(self, conv: Conversation, turn_idx: int, arrival_t: float):
        """Deterministic replay of conversation `conv` interrupted at turn
        `turn_idx`: rewind the session (force=True), rebuild the journaled
        context by re-prefilling it on a scheduler-chosen healthy replica
        through the arrival admission path (same backpressure as a fresh
        conversation), then resume the interrupted turn's decode. Replica
        determinism makes the recovered token streams byte-identical to a
        failure-free run; replay compute is charged to
        `replayed_prefill_tokens`, never to the victim's turn records."""
        cid = conv.cid
        self._gen[cid] = self._gen.get(cid, 0) + 1
        # the interrupted turn's already-published tokens are now stale;
        # this must publish BEFORE the replay path can emit the replacement
        # argmax token, so subscribers reset their (cid, turn_idx)
        # accumulation and the replay re-streams it byte-identically
        self._publish(EV_RECOVERY, self._now, cid=cid, turn_idx=turn_idx)
        self._slots.pop(cid, None)
        rec = self.records[cid]
        rec.recovered = True
        self.n_recoveries += 1
        self._recover_t0[cid] = self._now
        sess = self.sessions[cid]
        sess.node_id = None
        sess.turn_idx = turn_idx
        sess.transition(QUEUED, self._now, force=True)
        ctx = self._journal_context(conv, turn_idx)
        self.log.append(
            f"t={self._now:.3f} recovering cid {cid} at turn {turn_idx}: "
            f"re-prefilling {len(ctx)} journaled context tokens")
        pl = self.sched.place_first_prefill(view_of(conv), self.view)
        # replay backlog is real prefill backlog — schedulers must see it
        self.states[pl.node_id].queued_prefill_tokens += len(ctx)
        self._offer(pl.node_id,
                    Admission(cid, len(ctx),
                              lambda nid, conv=conv, idx=turn_idx,
                              at=arrival_t:
                              self._replay_prefill(conv, idx, nid, at),
                              kind="arrival"),
                    self._now)

    def _journal_context(self, conv: Conversation, turn_idx: int
                         ) -> np.ndarray:
        """The exact token sequence whose prefill rebuilds `conv`'s KV for
        resuming turn `turn_idx`: each completed turn's deterministic input
        followed by its journaled KV-fed stream, then the interrupted turn's
        input. Byte-identity of the replay rests on this being exact, so a
        journal/turn mismatch is kept loud."""
        done = self.journal.n_completed(conv.cid)
        if done != turn_idx:
            raise RuntimeError(
                f"journal holds {done} completed turns for conversation "
                f"{conv.cid} but recovery targets turn {turn_idx}")
        parts = []
        for t in range(turn_idx):
            parts.append(self._turn_tokens(conv, t))
            parts.append(np.asarray(
                self.journal.fed_tokens(conv.cid, t), np.int32))
        parts.append(self._turn_tokens(conv, turn_idx))
        return np.concatenate(parts) if len(parts) > 1 else parts[0]

    def _replay_prefill(self, conv: Conversation, turn_idx: int,
                        node_id: int, arrival_t: float):
        """Admitted recovery prefill: rebuild the journaled context in one
        AOT prefill dispatch, then rebind exactly like a turn-1 prefill —
        stay put on a decode-capable node, or one-shot transfer to a
        scheduler-chosen decoder."""
        node = self.replicas[node_id]
        st = self.states[node_id]
        start = max(self._now, self.clock[node_id])
        self.sessions[conv.cid].transition(PREFILLING, start)
        slot = node.kv.acquire()
        st.used_slots += 1
        ctx = self._journal_context(conv, turn_idx)
        fe = None
        if node.cfg.frontend != "none":
            fe = jnp.zeros((1, node.cfg.frontend_len or node.cfg.encoder_seq,
                            node.cfg.d_model), node.cfg.jnp_dtype)
        # replay splits at the SAME preamble boundary the original turn-1
        # did (the journaled ctx opens with it), so the rebuilt stream is
        # byte-identical to the failure-free run and the healthy node's
        # pool serves/repopulates the preamble exactly like a fresh arrival
        next_tok, dt = node.prefill_conversation(
            slot, ctx, fe, prefix_len=self._prefix_split(conv, node))
        dt = self._stretched(node_id, dt)
        self._sync_pool_state(node_id)
        done_t = start + dt
        self.clock[node_id] = done_t
        st.queued_prefill_tokens -= len(ctx)
        st.replayed_prefill_tokens += len(ctx)
        written = int(node.kv.lengths[slot])
        st.active_kv_tokens += written
        if node.role in ("decode", "mixed"):
            self._bind_done(conv, node_id, slot, int(next_tok), done_t,
                            turn_idx=turn_idx, arrival_t=arrival_t)
            return
        pkg = node.kv.export_slot(slot)
        node.kv.release(slot)
        st.used_slots -= 1
        st.active_kv_tokens -= written
        self._pump(node_id, self._now)
        bind = self.sched.bind_decoder(view_of(conv), self.view)
        self._offer(bind.node_id,
                    Admission(conv.cid, pkg["length"],
                              lambda nid, conv=conv, pkg=pkg,
                              nt=int(next_tok), done_t=done_t,
                              idx=turn_idx, at=arrival_t:
                              self._transfer_bind(conv, nid, pkg, nt,
                                                  max(done_t, self._now),
                                                  turn_idx=idx,
                                                  arrival_t=at)),
                    done_t)

    def _replace_admission(self, adm: Admission, now: float) -> Optional[int]:
        """Re-place one admission drained off a dead node through the SAME
        decision point that placed it (Runtime._drain_dead_node guards the
        returned target)."""
        conv = self._convs[adm.cid]
        if adm.kind == "arrival":
            return self.sched.place_first_prefill(view_of(conv),
                                                  self.view).node_id
        if adm.kind == "bind":
            return self.sched.bind_decoder(view_of(conv), self.view).node_id
        # a parked remote-turn package: the conversation is still bound
        # (with live KV) elsewhere — re-plan the whole turn placement from
        # scratch rather than re-offering a package that was never built
        sess = self.sessions[adm.cid]
        self._push(now, lambda idx=sess.turn_idx:
                   self._next_turn(conv, idx, now))
        return None

    def _tool_watchdog(self, conv: Conversation, next_idx: int, gen: int,
                       deadline_t: float):
        """TOOL_WAIT deadline: the session entered TOOL_WAIT before turn
        `next_idx` and its tool has not returned by `deadline_t`. "evict"
        frees the slot for waiting work — the tool return re-admits through
        journaled replay, exactly the dead-binding path; "fail" raises
        loudly. A watchdog that fires after the tool returned (or after the
        binding already died/recovered) is a no-op."""
        cid = conv.cid
        sess = self.sessions[cid]
        if (gen != self._gen.get(cid, 0) or sess.state != TOOL_WAIT
                or sess.turn_idx != next_idx or cid not in self._slots):
            return
        node_id, slot = self._slots[cid]
        if not self.states[node_id].alive:
            return  # binding already dead; the tool return replays anyway
        if self.tool_timeout_action == "fail":
            raise RuntimeError(
                f"conversation {cid} exceeded the tool deadline: turn "
                f"{next_idx} still TOOL_WAIT at t={deadline_t:.3f} "
                f"(tool_deadline_s={self.tool_deadline_s}); "
                f"tool_timeout_action='fail'")
        node = self.replicas[node_id]
        st = self.states[node_id]
        st.active_kv_tokens -= int(node.kv.lengths[slot])
        node.kv.release(slot)
        st.used_slots -= 1
        st.active_conversations -= 1
        self._slots.pop(cid)
        sess.node_id = None
        self.records[cid].n_tool_evictions += 1
        self.n_tool_evictions += 1
        self.log.append(
            f"t={deadline_t:.3f} tool deadline: evicted cid {cid} from "
            f"replica {node_id} (turn {next_idx} still waiting); slot freed "
            f"for parked work, tool return re-admits by replay")
        # the freed slot turns around into waiting work immediately
        self._pump(node_id, self._now)
        self._maybe_finish_draining(node_id, self._now)

    def inject_transfer_faults(self, n: int = 1) -> "EngineServer":
        """Arm `n` one-shot KV-transfer failures: each of the next `n`
        `_transfer_bind` attempts dies before any KV lands and retries with
        backoff on a freshly scheduler-chosen decoder (bounded by
        `max_transfer_retries`, then loud)."""
        self._transfer_fault_budget += int(n)
        return self
