"""A model replica: params + slot KV cache + jitted prefill/decode programs,
with bucketed prefill lengths (bounded recompilation) and greedy sampling.
Runs real forward passes on whatever devices are visible (CPU here; the same
code paths pjit onto a mesh slice in production).

Decode tail (the paper's memory-bound phase) is served by ONE jitted,
buffer-donated program per (chunk, ctx) bucket: `jax.lax.scan` over the
bucketed chunk length with on-device greedy sampling fed back as the next
token and the per-slot cache scatter fused into the step
(`fold_decode_step`), so XLA writes the donated KV buffers in place — no
per-token full-cache copy, one dispatch + one host sync per chunk instead
of per token. The scan is RAGGED: `decode_steps` takes a per-slot
`remaining` vector and each slot freezes (stops folding KV, stops
advancing its length, stops consuming tokens) once its own count is
exhausted, so a nearly-finished turn no longer collapses the chunk for
the whole batch — the agentic-trace irregularity the paper's
conversation-level view is meant to absorb. Fused programs are AOT
compiled (`jax.jit(...).lower(...).compile()`): compile time accumulates
in `compile_s` and never pollutes the measured per-chunk `dt` the server
feeds its logical clock and TBT EMA. `decode_step_all_reference` keeps
the original one-dispatch-per-token + host-side `append_step` copy path
as the parity oracle and benchmark baseline.

The (append-)prefill path (the paper's compute-bound phase, and the
turn-2+ hot-prefix appends PPD treats as their own latency class) gets
the same architecture: ONE AOT-compiled donated program per length
bucket (turn-1) or (length, prefix-ctx) bucket (append). The forward,
the logits gather at the last live position, greedy sampling, and the
per-slot KV write (a dynamic-slice scatter into the donated slot cache
pytree) all run inside the program — one dispatch, zero host-side KV
materialization, and no `export_slot_full` copy on the append path
(the prefix is a dynamic slice of the slot's own rows trimmed to its
ctx bucket). `prefill_mode="reference"` replays the eager per-op path
as the parity oracle; `warmup_prefill` pre-compiles buckets for cold
replicas, with compile seconds in `compile_s`, never in measured dt."""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model, build_model
from repro.models.config import ModelConfig

from .kvcache import (PrefixKVPool, SlotKVCache, fold_decode_step,
                      fold_prefill, prefix_hash, slice_slot_prefix)

PREFILL_BUCKETS = (32, 64, 128, 256, 512, 1024, 2048, 4096)

DECODE_CHUNKS = (1, 2, 4, 8, 16, 32)
CTX_BUCKET_MIN = 64

# Process-wide AOT prefill program cache. A compiled (append-)prefill
# executable is a pure function of (model config, cache geometry,
# attention impl, bucket key) — params and caches are ARGUMENTS — so
# replicas with identical signatures (every multi-replica deployment, and
# every engine a test builds) share one compile instead of each paying
# ~seconds per bucket. compile_s is charged only by the replica that
# actually compiled (a cache hit costs nothing and charges nothing).
_AOT_PREFILL_CACHE: Dict[Tuple, Any] = {}


def bucket_len(n: int) -> int:
    for b in PREFILL_BUCKETS:
        if n <= b:
            return b
    return -(-n // 4096) * 4096


def decode_chunk_bucket(n: int) -> int:
    """Smallest compiled scan length covering n steps (bounds recompiles;
    steps beyond the live count are masked out inside the scan)."""
    for b in DECODE_CHUNKS:
        if n <= b:
            return b
    return DECODE_CHUNKS[-1]


def decode_chunk_floor(n: int) -> int:
    """Largest compiled bucket <= n (floor 1): the chunk size a caller
    should dispatch so the scan runs at exactly its compiled length with no
    masked no-op tail. EngineServer._iterate and the decode_tail benchmark
    both size chunks through this, so policy and replay stay locked
    together."""
    f = 1
    for b in DECODE_CHUNKS:
        if b <= n:
            f = b
    return f


def ctx_bucket(n: int, max_ctx: int) -> int:
    """Power-of-two live-context bucket for the trimmed decode read."""
    b = CTX_BUCKET_MIN
    while b < n:
        b *= 2
    return min(b, max_ctx)


class ReplicaEngine:
    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 max_ctx: int = 2048, replica_id: int = 0, role: str = "decode",
                 warmup: bool = False, attention_impl: str = "xla",
                 prefill_mode: str = "jit", prefix_pool_tokens: int = 0):
        """attention_impl: "xla" (default) serves decode attention through the
        pure-jnp model path on every backend; "pallas" routes GQA decode
        attention through the flash-decode kernel (ops.decode_attention) and
        fresh global-attention prefill through the flash-prefill kernel —
        native on TPU, interpret-mode elsewhere. Threaded statically into the
        jitted programs, so switching never recompiles the jnp path.
        prefill_mode: "jit" (default) serves (append-)prefill through ONE
        AOT-compiled donated program per (length-bucket[, ctx-bucket]) — the
        per-slot KV write is a dynamic-slice scatter INSIDE the program, so
        a prefill is one dispatch with zero host-side KV materialization.
        "reference" replays the eager per-op path (host-side `write_prefill`
        copy; append reads the prefix via `export_slot_full`) — the parity
        oracle and benchmark baseline. Families the jitted path does not
        cover (exact-length recurrent prefill, encoder-decoder) fall back
        to the reference path regardless of the mode.
        prefix_pool_tokens: live-token budget for the node-level prefix KV
        pool (0 = no pool). A turn-1 prefill called with `prefix_len` > 0
        ALWAYS splits at that boundary (the split, not the pool, fixes the
        math — see prefill_conversation); the pool only changes where the
        prefix rows come from: a hit serves them through the fused
        shared-prefix program instead of recomputing them."""
        assert prefill_mode in ("jit", "reference")
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.kv = SlotKVCache(self.model, n_slots, max_ctx,
                              replica_id=replica_id)
        self.replica_id = replica_id
        self.role = role
        self.attention_impl = attention_impl
        self.exact_prefill = any(k in ("rwkv6", "rglru")
                                 for k in cfg.block_pattern)
        self.prefill_mode = prefill_mode
        # recurrent prefill consumes every position (padding would corrupt
        # state -> unbounded exact-length recompiles) and encdec lacks the
        # engine-mode prefill kwargs: both stay on the eager reference path
        self._prefill_jittable = (not self.exact_prefill
                                  and not cfg.is_encoder_decoder)
        self.compute_s = 0.0  # accumulated measured compute time
        self.compile_s = 0.0  # prefill+decode AOT compile time (OUT of dt)
        self.decode_s = 0.0   # decode-only share of compute_s: the
        #                       denominator of EFFECTIVE decode tokens/s
        #                       (n_decode_tokens / decode_s) — masked no-op
        #                       forwards and dispatch overhead both land
        #                       here, so the rotation win is measurable
        self.prefill_s = 0.0  # prefill-only share of compute_s (the
        #                       denominator of prefill tokens/s)
        self.n_prefill_tokens = 0
        self.n_decode_tokens = 0
        # node-level prefix KV pool (None = disabled). Pooled rows are
        # owned by NO slot and never donated: the fused shared-prefix
        # program reads them as a non-donated argument, so one entry can
        # feed any number of prefills while slot caches churn in place.
        self.prefix_pool = (PrefixKVPool(prefix_pool_tokens)
                            if prefix_pool_tokens > 0 else None)
        # prefix tokens served FROM the pool instead of recomputed —
        # the engine-side ground truth behind NodeState.pooled_prefix_hits
        self.n_pooled_prefix_tokens = 0

        self._decode = jax.jit(
            lambda p, t, c, pos, lens: self.model.decode_step(
                p, t, c, pos, kv_lens=lens,
                attention_impl=self.attention_impl))
        # fused donated decode programs, keyed by (scan length, ctx bucket)
        self._fused: Dict[Tuple[int, int], Any] = {}
        if warmup:
            self.warmup_decode()
            if self._prefill_jittable and prefill_mode == "jit":
                self.warmup_prefill()

    # ----- sampling -------------------------------------------------------------
    def sample(self, logits) -> np.ndarray:
        """Greedy over the true vocab (mask table padding)."""
        logits = logits[..., : self.cfg.vocab_size]
        return np.asarray(jnp.argmax(logits, axis=-1), np.int32)

    # ----- prefill ----------------------------------------------------------------
    def _use_jit_prefill(self) -> bool:
        return self.prefill_mode == "jit" and self._prefill_jittable

    def _check_prefill_room(self, slot: int, need: int):
        """The in-slot scatter would clamp at the buffer edge while host
        lengths advance past it — refuse loudly, naming the slot, in BOTH
        prefill modes (mirrors the decode_steps overflow guard)."""
        prev = int(self.kv.lengths[slot])
        if prev + need > self.kv.max_ctx:
            raise RuntimeError(
                f"prefill overflow on replica {self.replica_id}: slot {slot} "
                f"at length {prev} cannot take {need} more tokens "
                f"(max_ctx={self.kv.max_ctx})")

    def _prefill_pad(self, true_len: int, room: int) -> int:
        """Padded token length for a prefill whose slot has `room` positions
        left. Normally the length bucket — but the scatter writes the FULL
        padded region at the slot offset, and `dynamic_update_slice` clamps
        a start that would run off the buffer (silently corrupting the live
        prefix), so a nearly-full slot whose true length fits but whose
        bucket does not falls back to an exact-length program (a one-off
        compile in a regime bucketing cannot serve). Both prefill modes pad
        identically, keeping caches byte-comparable bit for bit."""
        pad = bucket_len(true_len)
        return pad if pad <= room else true_len

    def _build_prefill(self):
        """Turn-1 prefill program builder (the token bucket and frontend
        shape are fixed by the .lower() specs at the _get_prefill call
        site): forward over the padded bucket, logits gathered at the
        (traced) last live position,
        greedy argmax ON DEVICE, and the per-slot KV write as a donated
        dynamic-slice scatter into the slot cache pytree — one dispatch,
        zero host-side KV materialization. `slot` and `true_len` are traced
        scalars, so one compiled program serves every slot and every true
        length inside the bucket."""
        grouped, growing = self.kv._grouped, self.kv._growing
        vocab = self.cfg.vocab_size

        def run(params, caches, tokens, slot, true_len, fe):
            logits, new = self.model.prefill(
                params, tokens[None], frontend_embeds=fe,
                logits_at=true_len - 1,
                attention_impl=self.attention_impl)
            caches = fold_prefill(caches, new, slot, 0, grouped, growing)
            tok = jnp.argmax(logits[0, :vocab]).astype(jnp.int32)
            return caches, tok

        return jax.jit(run, donate_argnums=(1,))

    def _build_append(self, ctx: int):
        """Append-prefill program for one prefix ctx bucket (the token
        bucket is fixed by the .lower() specs at the _get_append call site):
        the hot prefix is a dynamic slice of the slot's own cache rows
        trimmed to `ctx` (no host-side `export_slot_full` copy), padding
        past the live length is masked via kv_lens, and the new tokens'
        KV scatters back into the slot at the (traced) previous length —
        the donated in-place contract of the fused decode scan, applied to
        the ConServe fast path."""
        grouped, growing = self.kv._grouped, self.kv._growing
        vocab = self.cfg.vocab_size

        def run(params, caches, tokens, slot, true_len, prev_len):
            prefix = slice_slot_prefix(caches, slot, ctx, grouped, growing)
            lens = jnp.reshape(prev_len.astype(jnp.int32), (1,))
            logits, new = self.model.prefill(
                params, tokens[None], caches=prefix, start_pos=prev_len,
                kv_lens=lens, prefix_start=0, logits_at=true_len - 1,
                attention_impl=self.attention_impl)
            caches = fold_prefill(caches, new, slot, prev_len, grouped,
                                  growing)
            tok = jnp.argmax(logits[0, :vocab]).astype(jnp.int32)
            return caches, tok

        return jax.jit(run, donate_argnums=(1,))

    def _aot_specs(self):
        spec = lambda x: jax.ShapeDtypeStruct(  # noqa: E731
            jnp.shape(x), x.dtype)
        return (jax.tree_util.tree_map(spec, self.params),
                jax.tree_util.tree_map(spec, self.kv.caches))

    def _prefill_cache_key(self, kind: str, *bucket) -> Tuple:
        """Process-wide cache key: everything the compiled executable is a
        function of besides its runtime arguments. cfg repr covers params
        and cache pytree structure; (n_slots, max_ctx) cover geometry."""
        return (repr(self.cfg), self.kv.n_slots, self.kv.max_ctx,
                self.attention_impl, kind, *bucket)

    def _get_prefill(self, pad_to: int, n_front: int):
        """Fetch (or AOT-compile) the turn-1 program for one token bucket.
        Compile time goes to `self.compile_s`, never into measured dt."""
        key = self._prefill_cache_key("prefill", pad_to, n_front)
        fn = _AOT_PREFILL_CACHE.get(key)
        if fn is None:
            t0 = time.perf_counter()
            pspec, cspec = self._aot_specs()
            scalar = jax.ShapeDtypeStruct((), jnp.int32)
            fe_spec = None if not n_front else jax.ShapeDtypeStruct(
                (1, n_front, self.cfg.d_model), self.cfg.jnp_dtype)
            fn = self._build_prefill().lower(
                pspec, cspec, jax.ShapeDtypeStruct((pad_to,), jnp.int32),
                scalar, scalar, fe_spec).compile()
            self.compile_s += time.perf_counter() - t0
            _AOT_PREFILL_CACHE[key] = fn
        return fn

    def _get_append(self, pad_to: int, ctx: int):
        """Fetch (or AOT-compile) the append program for one (token bucket,
        prefix ctx bucket). Compile time goes to `self.compile_s`."""
        key = self._prefill_cache_key("append", pad_to, ctx)
        fn = _AOT_PREFILL_CACHE.get(key)
        if fn is None:
            t0 = time.perf_counter()
            pspec, cspec = self._aot_specs()
            scalar = jax.ShapeDtypeStruct((), jnp.int32)
            fn = self._build_append(ctx).lower(
                pspec, cspec, jax.ShapeDtypeStruct((pad_to,), jnp.int32),
                scalar, scalar, scalar).compile()
            self.compile_s += time.perf_counter() - t0
            _AOT_PREFILL_CACHE[key] = fn
        return fn

    def _build_shared(self, ctx: int):
        """Shared-prefix prefill program for one pooled ctx bucket (the
        delta-token bucket is fixed by the .lower() specs at the _get_shared
        call site) — the third prefill class: append-against-shared-prefix.
        The POOLED rows (a non-donated argument shaped exactly like
        `slice_slot_prefix`'s output) are first scattered into the slot at
        offset 0 — the slot physically holds the full context afterwards,
        same as if it had prefilled the preamble itself — then the delta
        forward reads them back through the SAME `slice_slot_prefix` read
        the append class uses, and the delta's KV scatters in at the traced
        previous length. Byte-equality with the recompute path (turn-1
        program on the preamble + append program on the delta) is a tested
        property, not an aspiration: same reads, same folds, same programs
        downstream."""
        grouped, growing = self.kv._grouped, self.kv._growing
        vocab = self.cfg.vocab_size

        def run(params, caches, pool, tokens, slot, true_len, prev_len):
            caches = fold_prefill(caches, pool, slot, 0, grouped, growing)
            prefix = slice_slot_prefix(caches, slot, ctx, grouped, growing)
            lens = jnp.reshape(prev_len.astype(jnp.int32), (1,))
            logits, new = self.model.prefill(
                params, tokens[None], caches=prefix, start_pos=prev_len,
                kv_lens=lens, prefix_start=0, logits_at=true_len - 1,
                attention_impl=self.attention_impl)
            caches = fold_prefill(caches, new, slot, prev_len, grouped,
                                  growing)
            tok = jnp.argmax(logits[0, :vocab]).astype(jnp.int32)
            return caches, tok

        return jax.jit(run, donate_argnums=(1,))

    def _pool_specs(self, ctx: int):
        """ShapeDtypeStructs of a pooled entry at ctx bucket `ctx` — by
        construction the exact output shape of `slice_slot_prefix`."""
        grouped, growing = self.kv._grouped, self.kv._growing
        _, cspec = self._aot_specs()
        return jax.eval_shape(
            lambda c: slice_slot_prefix(c, jnp.int32(0), ctx, grouped,
                                        growing), cspec)

    def _get_shared(self, pad_to: int, ctx: int):
        """Fetch (or AOT-compile) the shared-prefix program for one (delta
        token bucket, pooled ctx bucket). Compile time goes to
        `self.compile_s`, never into measured dt."""
        key = self._prefill_cache_key("shared", pad_to, ctx)
        fn = _AOT_PREFILL_CACHE.get(key)
        if fn is None:
            t0 = time.perf_counter()
            pspec, cspec = self._aot_specs()
            scalar = jax.ShapeDtypeStruct((), jnp.int32)
            fn = self._build_shared(ctx).lower(
                pspec, cspec, self._pool_specs(ctx),
                jax.ShapeDtypeStruct((pad_to,), jnp.int32),
                scalar, scalar, scalar).compile()
            self.compile_s += time.perf_counter() - t0
            _AOT_PREFILL_CACHE[key] = fn
        return fn

    def warmup_prefill(self, lengths=None, ctx_limits=None) -> float:
        """Pre-compile the AOT prefill programs so a cold replica never
        charges a compile to its first conversations' TTFT. `lengths`
        defaults to every PREFILL_BUCKET reachable under max_ctx; turn-1
        programs compile per length, append programs per (length, ctx)
        pair with `ctx_limits` defaulting to every power-of-two ctx bucket
        a prefix could occupy. Returns seconds spent compiling (also
        accumulated in `self.compile_s`). No-op for families the jitted
        path does not cover."""
        if not self._prefill_jittable:
            return 0.0
        if lengths is None:
            lengths = [b for b in PREFILL_BUCKETS if b <= self.kv.max_ctx]
        if ctx_limits is None:
            ctx_limits = []
            b = CTX_BUCKET_MIN
            while b < self.kv.max_ctx:
                ctx_limits.append(b)
                b *= 2
            ctx_limits.append(self.kv.max_ctx)
        before = self.compile_s
        n_front = 0
        if self.cfg.frontend != "none" and self.cfg.frontend_len:
            n_front = self.cfg.frontend_len
        for L in dict.fromkeys(bucket_len(int(x)) for x in lengths):
            self._get_prefill(L, n_front)
            for C in dict.fromkeys(ctx_bucket(int(c), self.kv.max_ctx)
                                   for c in ctx_limits):
                # skip (L, C) pairs no live slot could ever reach: the
                # smallest prefix length in ctx bucket C plus the append
                # must still fit the slot
                min_prev = 0 if C <= CTX_BUCKET_MIN else C // 2 + 1
                if min_prev + L <= self.kv.max_ctx:
                    self._get_append(L, C)
                    if self.prefix_pool is not None:
                        self._get_shared(L, C)
        return self.compile_s - before

    def prefill_conversation(self, slot: int, tokens: np.ndarray,
                             frontend_embeds=None, prefix_len: int = 0
                             ) -> Tuple[np.ndarray, float]:
        """Turn-1 prefill into `slot`. Returns (next_token, measured_s);
        AOT compile time (cold bucket) is charged to `self.compile_s`,
        never to the returned dt.

        `prefix_len` > 0 declares tokens[:prefix_len] a SHARED PREAMBLE and
        ALWAYS splits the prefill at that boundary — turn-1 class on the
        preamble, append class on the delta — whether or not a pool is
        configured or holds the rows. The split, not the pool, fixes the
        math: both the pool-hit and the recompute path run the same
        masked forward over the same prefix-read downstream, so per-turn
        token streams are byte-identical pool-on vs pool-off. The pool
        only changes WHERE the preamble rows come from: a hit folds the
        pooled rows into the slot (one fused dispatch, zero preamble
        FLOPs); a miss recomputes them and then materializes zero-masked
        copies into the pool for the next conversation."""
        true_len = len(tokens)
        if prefix_len:
            if not 0 < prefix_len < true_len:
                raise ValueError(
                    f"prefill_conversation: prefix_len {prefix_len} must be "
                    f"in (0, {true_len}) — the turn needs a non-empty delta "
                    f"after the shared preamble")
            if frontend_embeds is not None:
                raise ValueError(
                    "prefill_conversation: shared-prefix split does not "
                    "compose with frontend embeds")
            return self._prefill_split(slot, np.asarray(tokens, np.int32),
                                       int(prefix_len))
        n_front = 0
        if self.cfg.frontend != "none" and frontend_embeds is not None:
            n_front = frontend_embeds.shape[1]
        self._check_prefill_room(slot, n_front + true_len)
        if not self._use_jit_prefill():
            return self._prefill_reference(slot, tokens, frontend_embeds,
                                           n_front)
        pad_to = self._prefill_pad(true_len, self.kv.max_ctx - n_front)
        fn = self._get_prefill(pad_to, n_front)  # compile OFF the clock
        toks = np.zeros(pad_to, np.int32)
        toks[:true_len] = tokens
        t0 = time.perf_counter()
        caches, tok = fn(self.params, self.kv.caches, jnp.asarray(toks),
                         np.int32(slot), np.int32(true_len), frontend_embeds)
        tok = jax.block_until_ready(tok)
        self.kv.caches = caches  # donated: old buffers are dead
        self.kv.lengths[slot] = n_front + true_len
        dt = time.perf_counter() - t0
        self.compute_s += dt
        self.prefill_s += dt
        self.n_prefill_tokens += true_len
        return np.int32(tok), dt

    def _prefill_split(self, slot: int, tokens: np.ndarray, prefix_len: int
                       ) -> Tuple[np.ndarray, float]:
        """Shared-preamble turn-1 prefill: the always-split path behind
        `prefill_conversation(prefix_len=...)`. Pool hit -> fused
        shared-prefix program (or the host-side fold + eager append in
        reference mode); miss or no pool -> turn-1 class on the preamble,
        pool populate (when enabled), append class on the delta."""
        self._check_prefill_room(slot, len(tokens))
        prefix = tokens[:prefix_len]
        delta = tokens[prefix_len:]
        pool = self.prefix_pool
        key = prefix_hash(prefix) if pool is not None else None
        if pool is not None and pool.contains(key):
            return self._prefill_from_pool(slot, key, delta, prefix_len)
        # Miss (or no pool): recompute the preamble through the normal
        # turn-1 class, then serve the delta through the append class —
        # the exact programs a pool hit replays, so the streams match.
        tok_p, dt = self.prefill_conversation(slot, prefix)
        del tok_p  # the preamble's sampled token is never emitted
        if pool is not None:
            t0 = time.perf_counter()
            ctx = ctx_bucket(prefix_len, self.kv.max_ctx)
            rows = self._materialize_prefix(slot, prefix_len, ctx)
            pool.put(key, rows, prefix_len, ctx)
            export_dt = time.perf_counter() - t0
            self.compute_s += export_dt
            self.prefill_s += export_dt
            dt += export_dt
        tok, dt_a = self.append_prefill(slot, delta)
        return tok, dt + dt_a

    def _materialize_prefix(self, slot: int, length: int, ctx: int):
        """Copy a slot's first `length` cache rows out at ctx bucket `ctx`,
        zero-masked beyond `length` — the immutable pooled representation.
        Must run BEFORE the delta append touches the slot (fixed-state
        leaves would otherwise reflect the full context) and before any
        donated program kills the buffers the slice reads."""
        grouped, growing = self.kv._grouped, self.kv._growing
        rows = slice_slot_prefix(self.kv.caches, jnp.int32(slot), ctx,
                                 grouped, growing)

        def mask(leaf, g, gr):
            if not gr:
                return leaf
            if g:  # (G, 1, ctx, ...)
                pos = jnp.arange(leaf.shape[2]).reshape(
                    (1, 1, -1) + (1,) * (leaf.ndim - 3))
            else:  # (1, ctx, ...)
                pos = jnp.arange(leaf.shape[1]).reshape(
                    (1, -1) + (1,) * (leaf.ndim - 2))
            return jnp.where(pos < length, leaf, jnp.zeros_like(leaf))

        rows = jax.tree_util.tree_map(mask, rows, grouped, growing)
        return jax.block_until_ready(rows)

    def _prefill_from_pool(self, slot: int, key: str, delta: np.ndarray,
                           prefix_len: int) -> Tuple[np.ndarray, float]:
        """Pool-hit turn-1: fold the pooled preamble rows into the slot and
        run the delta forward against them — zero preamble FLOPs. The entry
        is pinned across the read so eviction can never rip the rows out
        from under the dispatch; `get` records the observed hit the
        eviction rule orders on."""
        pool = self.prefix_pool
        e = pool.get(key)
        pool.pin(key)
        try:
            true_len = len(delta)
            if not self._use_jit_prefill():
                # reference mode: host-side fold of the pooled rows, then
                # the eager append oracle over them
                t0 = time.perf_counter()
                self.kv.caches = fold_prefill(
                    self.kv.caches, e.caches, slot, 0,
                    self.kv._grouped, self.kv._growing)
                self.kv.lengths[slot] = prefix_len
                fold_dt = time.perf_counter() - t0
                self.compute_s += fold_dt
                self.prefill_s += fold_dt
                tok, dt = self._append_reference(slot, delta)
                self.n_pooled_prefix_tokens += prefix_len
                return tok, fold_dt + dt
            pad_to = self._prefill_pad(true_len,
                                       self.kv.max_ctx - prefix_len)
            fn = self._get_shared(pad_to, e.ctx)  # compile OFF the clock
            toks = np.zeros(pad_to, np.int32)
            toks[:true_len] = delta
            t0 = time.perf_counter()
            caches, tok = fn(self.params, self.kv.caches, e.caches,
                             jnp.asarray(toks), np.int32(slot),
                             np.int32(true_len), np.int32(prefix_len))
            tok = jax.block_until_ready(tok)
            self.kv.caches = caches  # donated: old buffers are dead
            self.kv.lengths[slot] = prefix_len + true_len
            dt = time.perf_counter() - t0
            self.compute_s += dt
            self.prefill_s += dt
            self.n_prefill_tokens += true_len
            self.n_pooled_prefix_tokens += prefix_len
            return np.int32(tok), dt
        finally:
            pool.unpin(key)

    def _prefill_reference(self, slot: int, tokens: np.ndarray,
                           frontend_embeds, n_front: int
                           ) -> Tuple[np.ndarray, float]:
        """REFERENCE PATH (pre-AOT): eager per-op forward + host-side
        `write_prefill` copy. The parity oracle and benchmark baseline."""
        t0 = time.perf_counter()
        true_len = len(tokens)
        pad_to = true_len if self.exact_prefill else self._prefill_pad(
            true_len, self.kv.max_ctx - n_front)
        toks = np.zeros(pad_to, np.int32)
        toks[:true_len] = tokens
        logits, caches = self.model.prefill(
            self.params, jnp.asarray(toks)[None],
            frontend_embeds=frontend_embeds,
            logits_at=true_len - 1 if pad_to != true_len else None)
        logits = jax.block_until_ready(logits)
        self.kv.write_prefill(slot, caches, n_front + true_len)
        dt = time.perf_counter() - t0
        self.compute_s += dt
        self.prefill_s += dt
        self.n_prefill_tokens += true_len
        return self.sample(logits)[0], dt

    def append_prefill(self, slot: int, tokens: np.ndarray
                       ) -> Tuple[np.ndarray, float]:
        """Turn-2+ prefill against the slot's cached prefix (local, prefix
        cache hit — the ConServe fast path). Returns (next_token,
        measured_s); AOT compile time is charged to `self.compile_s`."""
        true_len = len(tokens)
        self._check_prefill_room(slot, true_len)
        if not self._use_jit_prefill():
            return self._append_reference(slot, tokens)
        prev = int(self.kv.lengths[slot])
        pad_to = self._prefill_pad(true_len, self.kv.max_ctx - prev)
        ctx = ctx_bucket(max(prev, 1), self.kv.max_ctx)
        fn = self._get_append(pad_to, ctx)  # compile OFF the clock
        toks = np.zeros(pad_to, np.int32)
        toks[:true_len] = tokens
        t0 = time.perf_counter()
        caches, tok = fn(self.params, self.kv.caches, jnp.asarray(toks),
                         np.int32(slot), np.int32(true_len), np.int32(prev))
        tok = jax.block_until_ready(tok)
        self.kv.caches = caches  # donated: old buffers are dead
        self.kv.lengths[slot] = prev + true_len
        dt = time.perf_counter() - t0
        self.compute_s += dt
        self.prefill_s += dt
        self.n_prefill_tokens += true_len
        return np.int32(tok), dt

    def _append_reference(self, slot: int, tokens: np.ndarray
                          ) -> Tuple[np.ndarray, float]:
        """REFERENCE PATH (pre-AOT): eager forward over the full-buffer
        prefix view (`export_slot_full` host-side copy) + host-side
        `write_prefill`. The parity oracle and benchmark baseline."""
        t0 = time.perf_counter()
        true_len = len(tokens)
        prev = int(self.kv.lengths[slot])
        pad_to = true_len if self.exact_prefill else self._prefill_pad(
            true_len, self.kv.max_ctx - prev)
        toks = np.zeros(pad_to, np.int32)
        toks[:true_len] = tokens
        prefix = self.kv.export_slot_full(slot)
        lens = jnp.asarray([prev], jnp.int32)
        logits, caches = self.model.prefill(
            self.params, jnp.asarray(toks)[None], caches=prefix,
            start_pos=prev, kv_lens=lens, prefix_start=0,
            logits_at=true_len - 1 if pad_to != true_len else None)
        logits = jax.block_until_ready(logits)
        self.kv.write_prefill(slot, caches, prev + true_len)
        dt = time.perf_counter() - t0
        self.compute_s += dt
        self.prefill_s += dt
        self.n_prefill_tokens += true_len
        return self.sample(logits)[0], dt

    # ----- decode -----------------------------------------------------------------
    def _build_fused(self, n_steps: int, ctx_limit: Optional[int]):
        """Fused decode program: scan over `n_steps` iterations with
        on-device greedy sampling fed back as the next token and the
        per-slot cache scatter fused into the step. The cache pytree is
        DONATED — XLA aliases the input buffers into the outputs, so the
        decode tail appends in place instead of copying every leaf per
        token. The scan is ragged: `remaining` is a per-slot step count and
        slot s is a masked no-op from step remaining[s] on (its KV stops
        folding, its length stops advancing, its fed-back token freezes),
        so one compiled bucket serves any mix of per-slot chunk lengths up
        to n_steps."""
        grouped, growing = self.kv._grouped, self.kv._growing
        vocab = self.cfg.vocab_size

        def run(params, caches, tokens, lens, emit, remaining):
            def body(carry, i):
                caches, lens, tokens = carry
                logits, updates = self.model.decode_step(
                    params, tokens, caches, lens, kv_lens=lens,
                    ctx_limit=ctx_limit,
                    attention_impl=self.attention_impl)
                sampled = jnp.argmax(logits[:, :vocab], axis=-1).astype(
                    jnp.int32)
                live = emit & (i < remaining)
                caches = fold_decode_step(caches, updates, lens, live,
                                          grouped, growing)
                lens = lens + live.astype(lens.dtype)
                tokens = jnp.where(live, sampled, tokens)
                return (caches, lens, tokens), sampled

            (caches, lens, tokens), seq = jax.lax.scan(
                body, (caches, lens, tokens), jnp.arange(n_steps))
            return caches, seq

        return jax.jit(run, donate_argnums=(1,))

    def _get_fused(self, n_steps: int, ctx_limit: int):
        """Fetch (or AOT-compile) the fused program for one (chunk, ctx)
        bucket. Compile time goes to `self.compile_s`, NOT into any
        measured decode dt — first bucket hits no longer pollute the
        server's logical clock or the observed TBT EMA."""
        key = (n_steps, ctx_limit)
        fn = self._fused.get(key)
        if fn is None:
            t0 = time.perf_counter()
            spec = lambda x: jax.ShapeDtypeStruct(  # noqa: E731
                jnp.shape(x), x.dtype)
            vec = lambda dt: jax.ShapeDtypeStruct(  # noqa: E731
                (self.kv.n_slots,), dt)
            fn = self._build_fused(n_steps, ctx_limit).lower(
                jax.tree_util.tree_map(spec, self.params),
                jax.tree_util.tree_map(spec, self.kv.caches),
                vec(jnp.int32), vec(jnp.int32), vec(jnp.bool_),
                vec(jnp.int32)).compile()
            self.compile_s += time.perf_counter() - t0
            self._fused[key] = fn
        return fn

    def warmup_decode(self, chunks=None, ctx_limits=None) -> float:
        """Pre-compile fused decode programs so serving never hits a cold
        (chunk, ctx) bucket. Defaults cover every bucket reachable on this
        replica: all DECODE_CHUNKS × all power-of-two ctx buckets up to
        max_ctx. Returns the seconds spent compiling (also accumulated in
        `self.compile_s`)."""
        if ctx_limits is None:
            ctx_limits = []
            b = CTX_BUCKET_MIN
            while b < self.kv.max_ctx:
                ctx_limits.append(b)
                b *= 2
            ctx_limits.append(self.kv.max_ctx)
        before = self.compile_s
        for c in (chunks if chunks is not None else DECODE_CHUNKS):
            for cl in dict.fromkeys(int(x) for x in ctx_limits):
                self._get_fused(decode_chunk_bucket(int(c)), cl)
        return self.compile_s - before

    def _remaining_vector(self, emit_mask: np.ndarray,
                          remaining) -> np.ndarray:
        """Normalize `remaining` (scalar or per-slot vector) into a
        validated per-slot int32 vector, enforcing the per-slot overflow
        guard (raises naming the offending slot, not the batch max)."""
        if np.ndim(remaining) == 0:
            n = int(max(1, min(int(remaining), DECODE_CHUNKS[-1])))
            rem = np.where(emit_mask, n, 0).astype(np.int32)
        else:
            rem = np.asarray(remaining, np.int32).copy()
            if rem.shape != emit_mask.shape:
                raise ValueError(
                    f"decode_steps: remaining shape {rem.shape} != "
                    f"emit_mask shape {emit_mask.shape}")
            rem[~emit_mask] = 0
            bad = emit_mask & (rem <= 0)
            if bad.any():
                raise ValueError(
                    "decode_steps: emitting slot(s) "
                    f"{np.flatnonzero(bad).tolist()} have non-positive "
                    "remaining")
            big = emit_mask & (rem > DECODE_CHUNKS[-1])
            if big.any():
                # the contract is 'slot s consumes EXACTLY remaining[s]
                # tokens' — silently clamping would desync the caller's
                # bookkeeping from kv.lengths, so refuse instead
                s = int(np.flatnonzero(big)[0])
                raise ValueError(
                    f"decode_steps: slot {s} remaining {int(rem[s])} "
                    f"exceeds the largest compiled chunk "
                    f"{DECODE_CHUNKS[-1]}; chunk the call")
        over = emit_mask & (self.kv.lengths + rem > self.kv.max_ctx)
        if over.any():
            s = int(np.flatnonzero(over)[0])
            # the in-scan scatter would clamp at the last position while
            # host lengths advance past the buffer — refuse loudly here so
            # every caller gets the guarantee, not just EngineServer
            raise RuntimeError(
                f"decode_steps overflow: slot {s} at length "
                f"{int(self.kv.lengths[s])} cannot take {int(rem[s])} more "
                f"tokens (max_ctx={self.kv.max_ctx})")
        return rem

    def decode_steps(self, next_tokens: np.ndarray, emit_mask: np.ndarray,
                     remaining) -> Tuple[np.ndarray, float]:
        """Run one RAGGED fused decode chunk across ALL slots in ONE
        dispatch (inactive slots compute in lockstep but are masked out).

        `remaining` is either a scalar int — every emitting slot consumes
        exactly that many tokens (clamped into [1, DECODE_CHUNKS[-1]], the
        historic contract) — or a per-slot int vector: slot s consumes
        exactly remaining[s] tokens (each must be in [1, DECODE_CHUNKS[-1]];
        larger values raise rather than silently clamp), then freezes
        mid-scan while longer-running neighbors continue to
        max(remaining). Returns
        (sampled (max(remaining), n_slots) int32 matrix in step order —
        rows >= remaining[s] are dead for slot s — and measured execution
        seconds; AOT compile time is charged to `self.compile_s`, never to
        the returned dt).

        SPLIT-CHUNK CONTRACT (what the server's rotation loop relies on):
        `decode_steps` is callable back-to-back on the same donated cache,
        and slots may JOIN between calls — a slot prefilled (or imported)
        after call k participates in call k+1 exactly as if the whole
        sequence had been one dispatch schedule from the start. This is
        sound by construction, not by convention: each lane's math reads
        only its own slot's cache row and length, a frozen/inactive lane's
        row is select-guarded to byte-identity (`fold_decode_step`), and
        per-slot lengths advance by exactly the consumed share — so ANY
        partition of a turn's remaining tokens into chunk cuts, interleaved
        with other slots joining or finishing, yields byte-identical
        per-slot tokens and cache state (locked down by the rotation
        hypothesis property in tests/test_scheduler_properties.py)."""
        emit_mask = np.asarray(emit_mask, bool)
        rem = self._remaining_vector(emit_mask, remaining)
        n_max = int(rem.max()) if emit_mask.any() else 1
        n_max = max(1, n_max)
        n_steps = decode_chunk_bucket(n_max)
        live_max = int(self.kv.lengths[emit_mask].max()) if emit_mask.any() \
            else 0
        ctx_limit = ctx_bucket(live_max + n_steps, self.kv.max_ctx)
        fn = self._get_fused(n_steps, ctx_limit)
        t0 = time.perf_counter()
        caches, seq = fn(self.params, self.kv.caches,
                         jnp.asarray(next_tokens, jnp.int32),
                         jnp.asarray(self.kv.lengths),
                         jnp.asarray(emit_mask), jnp.asarray(rem))
        seq = np.asarray(jax.block_until_ready(seq))[:n_max]
        self.kv.caches = caches  # donated: old buffers are dead
        self.kv.lengths += np.where(emit_mask, rem, 0).astype(np.int32)
        dt = time.perf_counter() - t0
        self.compute_s += dt
        self.decode_s += dt
        self.n_decode_tokens += int(rem[emit_mask].sum())
        return seq, dt

    def decode_step_all(self, next_tokens: np.ndarray,
                        emit_mask: np.ndarray) -> Tuple[np.ndarray, float]:
        """One continuous-batching iteration across ALL slots via the fused
        in-place path. Returns (sampled (n_slots,), measured_s)."""
        seq, dt = self.decode_steps(next_tokens, emit_mask, 1)
        return seq[0], dt

    def decode_step_all_reference(self, next_tokens: np.ndarray,
                                  emit_mask: np.ndarray
                                  ) -> Tuple[np.ndarray, float]:
        """REFERENCE PATH (pre-fusion): one jitted dispatch + host sync +
        host-side argmax per token, cache append via the copying
        `append_step`. Kept as the parity oracle and benchmark baseline."""
        t0 = time.perf_counter()
        lens = self.kv.kv_lens()
        logits, updates = self._decode(
            self.params, jnp.asarray(next_tokens), self.kv.caches,
            self.kv.positions(), lens)
        logits = jax.block_until_ready(logits)
        self.kv.append_step(updates, emit_mask)
        dt = time.perf_counter() - t0
        self.compute_s += dt
        self.decode_s += dt
        self.n_decode_tokens += int(emit_mask.sum())
        return self.sample(logits), dt
