"""A model replica: params + slot KV cache + jitted prefill/decode programs,
with bucketed prefill lengths (bounded recompilation) and greedy sampling.
Runs real forward passes on whatever devices are visible (CPU here; the same
code paths pjit onto a mesh slice in production).

Decode tail (the paper's memory-bound phase) is served by ONE jitted,
buffer-donated program per (chunk, ctx) bucket: `jax.lax.scan` over up to
`n` decode iterations with on-device greedy sampling fed back as the next
token and the per-slot cache scatter fused into the step
(`fold_decode_step`), so XLA writes the donated KV buffers in place — no
per-token full-cache copy, one dispatch + one host sync per chunk instead
of per token. `decode_step_all_reference` keeps the original
one-dispatch-per-token + host-side `append_step` copy path as the parity
oracle and benchmark baseline."""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model, build_model
from repro.models.config import ModelConfig

from .kvcache import SlotKVCache, fold_decode_step

PREFILL_BUCKETS = (32, 64, 128, 256, 512, 1024, 2048, 4096)

DECODE_CHUNKS = (1, 2, 4, 8, 16, 32)
CTX_BUCKET_MIN = 64


def bucket_len(n: int) -> int:
    for b in PREFILL_BUCKETS:
        if n <= b:
            return b
    return -(-n // 4096) * 4096


def decode_chunk_bucket(n: int) -> int:
    """Smallest compiled scan length covering n steps (bounds recompiles;
    steps beyond the live count are masked out inside the scan)."""
    for b in DECODE_CHUNKS:
        if n <= b:
            return b
    return DECODE_CHUNKS[-1]


def ctx_bucket(n: int, max_ctx: int) -> int:
    """Power-of-two live-context bucket for the trimmed decode read."""
    b = CTX_BUCKET_MIN
    while b < n:
        b *= 2
    return min(b, max_ctx)


class ReplicaEngine:
    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 max_ctx: int = 2048, replica_id: int = 0, role: str = "decode"):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.kv = SlotKVCache(self.model, n_slots, max_ctx)
        self.replica_id = replica_id
        self.role = role
        self.exact_prefill = any(k in ("rwkv6", "rglru")
                                 for k in cfg.block_pattern)
        self.compute_s = 0.0  # accumulated measured compute time
        self.n_prefill_tokens = 0
        self.n_decode_tokens = 0

        self._decode = jax.jit(
            lambda p, t, c, pos, lens: self.model.decode_step(
                p, t, c, pos, kv_lens=lens))
        # fused donated decode programs, keyed by (scan length, ctx bucket)
        self._fused: Dict[Tuple[int, int], Any] = {}

    # ----- sampling -------------------------------------------------------------
    def sample(self, logits) -> np.ndarray:
        """Greedy over the true vocab (mask table padding)."""
        logits = logits[..., : self.cfg.vocab_size]
        return np.asarray(jnp.argmax(logits, axis=-1), np.int32)

    # ----- prefill ----------------------------------------------------------------
    def prefill_conversation(self, slot: int, tokens: np.ndarray,
                             frontend_embeds=None) -> Tuple[np.ndarray, float]:
        """Turn-1 prefill into `slot`. Returns (next_token, measured_s)."""
        t0 = time.perf_counter()
        true_len = len(tokens)
        pad_to = true_len if self.exact_prefill else bucket_len(true_len)
        toks = np.zeros(pad_to, np.int32)
        toks[:true_len] = tokens
        logits, caches = self.model.prefill(
            self.params, jnp.asarray(toks)[None],
            frontend_embeds=frontend_embeds,
            logits_at=true_len - 1 if pad_to != true_len else None)
        logits = jax.block_until_ready(logits)
        n_front = 0
        if self.cfg.frontend != "none" and frontend_embeds is not None:
            n_front = frontend_embeds.shape[1]
        self.kv.write_prefill(slot, caches, n_front + true_len)
        dt = time.perf_counter() - t0
        self.compute_s += dt
        self.n_prefill_tokens += true_len
        return self.sample(logits)[0], dt

    def append_prefill(self, slot: int, tokens: np.ndarray
                       ) -> Tuple[np.ndarray, float]:
        """Turn-2+ prefill against the slot's cached prefix (local, prefix
        cache hit — the ConServe fast path)."""
        t0 = time.perf_counter()
        true_len = len(tokens)
        prev = int(self.kv.lengths[slot])
        pad_to = true_len if self.exact_prefill else bucket_len(true_len)
        toks = np.zeros(pad_to, np.int32)
        toks[:true_len] = tokens
        prefix = self.kv.export_slot_full(slot)
        lens = jnp.asarray([prev], jnp.int32)
        logits, caches = self.model.prefill(
            self.params, jnp.asarray(toks)[None], caches=prefix,
            start_pos=prev, kv_lens=lens, prefix_start=0,
            logits_at=true_len - 1 if pad_to != true_len else None)
        logits = jax.block_until_ready(logits)
        self.kv.write_prefill(slot, caches, prev + true_len)
        dt = time.perf_counter() - t0
        self.compute_s += dt
        self.n_prefill_tokens += true_len
        return self.sample(logits)[0], dt

    # ----- decode -----------------------------------------------------------------
    def _build_fused(self, n_steps: int, ctx_limit: Optional[int]):
        """Jitted fused decode program: scan over `n_steps` iterations with
        on-device greedy sampling fed back as the next token and the
        per-slot cache scatter fused into the step. The cache pytree is
        DONATED — XLA aliases the input buffers into the outputs, so the
        decode tail appends in place instead of copying every leaf per
        token. Steps with index >= n_live are masked no-ops (lets one
        compiled bucket serve any chunk size up to n_steps)."""
        grouped, growing = self.kv._grouped, self.kv._growing
        vocab = self.cfg.vocab_size

        def run(params, caches, tokens, lens, emit, n_live):
            def body(carry, i):
                caches, lens, tokens = carry
                logits, updates = self.model.decode_step(
                    params, tokens, caches, lens, kv_lens=lens,
                    ctx_limit=ctx_limit)
                sampled = jnp.argmax(logits[:, :vocab], axis=-1).astype(
                    jnp.int32)
                live = emit & (i < n_live)
                caches = fold_decode_step(caches, updates, lens, live,
                                          grouped, growing)
                lens = lens + live.astype(lens.dtype)
                tokens = jnp.where(live, sampled, tokens)
                return (caches, lens, tokens), sampled

            (caches, lens, tokens), seq = jax.lax.scan(
                body, (caches, lens, tokens), jnp.arange(n_steps))
            return caches, seq

        return jax.jit(run, donate_argnums=(1,))

    def decode_steps(self, next_tokens: np.ndarray, emit_mask: np.ndarray,
                     n: int) -> Tuple[np.ndarray, float]:
        """Run up to `n` fused decode iterations across ALL slots in ONE
        dispatch (inactive slots compute in lockstep but are masked out).
        Every emitting slot consumes exactly `n` tokens — the caller picks
        n <= min(remaining). Returns (sampled (n, n_slots) int32 matrix in
        step order, measured_s)."""
        n = int(max(1, min(n, DECODE_CHUNKS[-1])))
        t0 = time.perf_counter()
        n_steps = decode_chunk_bucket(n)
        live_max = int(self.kv.lengths[emit_mask].max()) if emit_mask.any() \
            else 0
        if live_max + n > self.kv.max_ctx:
            # the in-scan scatter would clamp at the last position while
            # host lengths advance past the buffer — refuse loudly here so
            # every caller gets the guarantee, not just EngineServer
            raise RuntimeError(
                f"decode_steps overflow: slot at length {live_max} cannot "
                f"take {n} more tokens (max_ctx={self.kv.max_ctx})")
        ctx_limit = ctx_bucket(live_max + n_steps, self.kv.max_ctx)
        key = (n_steps, ctx_limit)
        fn = self._fused.get(key)
        if fn is None:
            fn = self._fused[key] = self._build_fused(n_steps, ctx_limit)
        caches, seq = fn(self.params, self.kv.caches,
                         jnp.asarray(next_tokens, jnp.int32),
                         jnp.asarray(self.kv.lengths),
                         jnp.asarray(emit_mask), jnp.int32(n))
        seq = np.asarray(jax.block_until_ready(seq))[:n]
        self.kv.caches = caches  # donated: old buffers are dead
        self.kv.lengths[emit_mask] += n
        dt = time.perf_counter() - t0
        self.compute_s += dt
        self.n_decode_tokens += n * int(emit_mask.sum())
        return seq, dt

    def decode_step_all(self, next_tokens: np.ndarray,
                        emit_mask: np.ndarray) -> Tuple[np.ndarray, float]:
        """One continuous-batching iteration across ALL slots via the fused
        in-place path. Returns (sampled (n_slots,), measured_s)."""
        seq, dt = self.decode_steps(next_tokens, emit_mask, 1)
        return seq[0], dt

    def decode_step_all_reference(self, next_tokens: np.ndarray,
                                  emit_mask: np.ndarray
                                  ) -> Tuple[np.ndarray, float]:
        """REFERENCE PATH (pre-fusion): one jitted dispatch + host sync +
        host-side argmax per token, cache append via the copying
        `append_step`. Kept as the parity oracle and benchmark baseline."""
        t0 = time.perf_counter()
        lens = self.kv.kv_lens()
        logits, updates = self._decode(
            self.params, jnp.asarray(next_tokens), self.kv.caches,
            self.kv.positions(), lens)
        logits = jax.block_until_ready(logits)
        self.kv.append_step(updates, emit_mask)
        dt = time.perf_counter() - t0
        self.compute_s += dt
        self.n_decode_tokens += int(emit_mask.sum())
        return self.sample(logits), dt
