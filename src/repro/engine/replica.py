"""A model replica: params + slot KV cache + jitted prefill/decode programs,
with bucketed prefill lengths (bounded recompilation) and greedy sampling.
Runs real forward passes on whatever devices are visible (CPU here; the same
code paths pjit onto a mesh slice in production)."""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model, build_model
from repro.models.config import ModelConfig

from .kvcache import SlotKVCache

PREFILL_BUCKETS = (32, 64, 128, 256, 512, 1024, 2048, 4096)


def bucket_len(n: int) -> int:
    for b in PREFILL_BUCKETS:
        if n <= b:
            return b
    return -(-n // 4096) * 4096


class ReplicaEngine:
    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 max_ctx: int = 2048, replica_id: int = 0, role: str = "decode"):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.kv = SlotKVCache(self.model, n_slots, max_ctx)
        self.replica_id = replica_id
        self.role = role
        self.exact_prefill = any(k in ("rwkv6", "rglru")
                                 for k in cfg.block_pattern)
        self.compute_s = 0.0  # accumulated measured compute time
        self.n_prefill_tokens = 0
        self.n_decode_tokens = 0

        self._decode = jax.jit(
            lambda p, t, c, pos, lens: self.model.decode_step(
                p, t, c, pos, kv_lens=lens))

    # ----- sampling -------------------------------------------------------------
    def sample(self, logits) -> np.ndarray:
        """Greedy over the true vocab (mask table padding)."""
        logits = logits[..., : self.cfg.vocab_size]
        return np.asarray(jnp.argmax(logits, axis=-1), np.int32)

    # ----- prefill ----------------------------------------------------------------
    def prefill_conversation(self, slot: int, tokens: np.ndarray,
                             frontend_embeds=None) -> Tuple[np.ndarray, float]:
        """Turn-1 prefill into `slot`. Returns (next_token, measured_s)."""
        t0 = time.perf_counter()
        true_len = len(tokens)
        pad_to = true_len if self.exact_prefill else bucket_len(true_len)
        toks = np.zeros(pad_to, np.int32)
        toks[:true_len] = tokens
        logits, caches = self.model.prefill(
            self.params, jnp.asarray(toks)[None],
            frontend_embeds=frontend_embeds,
            logits_at=true_len - 1 if pad_to != true_len else None)
        logits = jax.block_until_ready(logits)
        n_front = 0
        if self.cfg.frontend != "none" and frontend_embeds is not None:
            n_front = frontend_embeds.shape[1]
        self.kv.write_prefill(slot, caches, n_front + true_len)
        dt = time.perf_counter() - t0
        self.compute_s += dt
        self.n_prefill_tokens += true_len
        return self.sample(logits)[0], dt

    def append_prefill(self, slot: int, tokens: np.ndarray
                       ) -> Tuple[np.ndarray, float]:
        """Turn-2+ prefill against the slot's cached prefix (local, prefix
        cache hit — the ConServe fast path)."""
        t0 = time.perf_counter()
        true_len = len(tokens)
        prev = int(self.kv.lengths[slot])
        pad_to = true_len if self.exact_prefill else bucket_len(true_len)
        toks = np.zeros(pad_to, np.int32)
        toks[:true_len] = tokens
        prefix = self.kv.export_slot_full(slot)
        lens = jnp.asarray([prev], jnp.int32)
        logits, caches = self.model.prefill(
            self.params, jnp.asarray(toks)[None], caches=prefix,
            start_pos=prev, kv_lens=lens, prefix_start=0,
            logits_at=true_len - 1 if pad_to != true_len else None)
        logits = jax.block_until_ready(logits)
        self.kv.write_prefill(slot, caches, prev + true_len)
        dt = time.perf_counter() - t0
        self.compute_s += dt
        self.n_prefill_tokens += true_len
        return self.sample(logits)[0], dt

    # ----- decode -----------------------------------------------------------------
    def decode_step_all(self, next_tokens: np.ndarray,
                        emit_mask: np.ndarray) -> Tuple[np.ndarray, float]:
        """One continuous-batching iteration across ALL slots (inactive slots
        compute in lockstep but are masked out). Returns (sampled (n_slots,),
        measured_s)."""
        t0 = time.perf_counter()
        lens = self.kv.kv_lens()
        logits, updates = self._decode(
            self.params, jnp.asarray(next_tokens), self.kv.caches,
            self.kv.positions(), lens)
        logits = jax.block_until_ready(logits)
        self.kv.append_step(updates, emit_mask)
        dt = time.perf_counter() - t0
        self.compute_s += dt
        self.n_decode_tokens += int(emit_mask.sum())
        return self.sample(logits), dt
