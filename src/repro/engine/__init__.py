from .kvcache import SlotKVCache
from .replica import ReplicaEngine, bucket_len
from .server import EngineServer
