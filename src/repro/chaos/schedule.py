"""Seeded, byte-identical chaos schedules.

A `ChaosSchedule` is a pure function of its seed: the same (seed, node_ids)
always generates the same event tuple, serializes to the same JSON bytes and
hashes to the same digest — so a chaos run is as replayable as the workload
it perturbs. Fault times are FRACTIONS of the fault-free serving span, not
absolute seconds: the same schedule scales to any workload once the driver
measures the baseline span.

Fault kinds compose the full failure surface the runtimes expose:

* ``kill``           — `fail_replica(node_id)`: the node dies, in-flight
                       work recovers by journaled deterministic replay.
* ``rejoin``         — `recover_replica(node_id)`: the corpse returns COLD
                       (caches invalidated, resident counters zero).
* ``slowdown``       — `inject_slowdown(node_id, factor)`: measured compute
                       durations stretch on the logical clock; slow, not
                       wrong. Feeds the observed-straggler quarantine.
* ``slowdown_end``   — `inject_slowdown(node_id, 1.0)`.
* ``transfer_fault`` — `inject_transfer_faults(n)`: the next n KV-transfer
                       binds fail once each and retry with bounded backoff.
* ``tool_timeout``   — applied to the WORKLOAD, not the runtime: a victim
                       conversation's mid-turn tool latency is inflated past
                       `tool_deadline_s`, forcing a watchdog eviction and
                       re-admission by replay (`driver.apply_tool_timeouts`).

Every generated schedule guarantees at least one kill -> rejoin cycle, one
sustained slowdown window (sized to trip an EMA-based quarantine and lift
while tails are still observable), one transfer fault and one tool timeout;
kill and slowdown pick DIFFERENT victims so the fleet never loses two
decode-capable nodes to faults at once.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from collections import Counter
from typing import Optional, Sequence, Tuple

import numpy as np

# ----- fault kinds -----------------------------------------------------------
FAULT_KILL = "kill"
FAULT_REJOIN = "rejoin"
FAULT_SLOWDOWN = "slowdown"
FAULT_SLOWDOWN_END = "slowdown_end"
FAULT_TRANSFER = "transfer_fault"
FAULT_TOOL_TIMEOUT = "tool_timeout"

FAULT_KINDS = (FAULT_KILL, FAULT_REJOIN, FAULT_SLOWDOWN, FAULT_SLOWDOWN_END,
               FAULT_TRANSFER, FAULT_TOOL_TIMEOUT)


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One scheduled fault. `at_frac` is the firing time as a fraction of
    the fault-free serving span; `node_id` names the victim for node faults,
    `factor` the slowdown multiplier, `n` the transfer-fault count and
    `conv_index` the tool-timeout victim selector (index into the workload's
    multi-turn conversations, sorted by cid)."""
    kind: str
    at_frac: float
    node_id: Optional[int] = None
    factor: float = 1.0
    n: int = 1
    conv_index: Optional[int] = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; valid: "
                             f"{', '.join(FAULT_KINDS)}")


@dataclasses.dataclass(frozen=True)
class ChaosSchedule:
    """An ordered, immutable fault plan plus the seed that produced it."""
    seed: int
    events: Tuple[ChaosEvent, ...]

    def to_json(self) -> str:
        """Canonical serialization — the determinism contract's byte form."""
        return json.dumps(
            {"seed": self.seed,
             "events": [dataclasses.asdict(e) for e in self.events]},
            sort_keys=True, separators=(",", ":"))

    @property
    def digest(self) -> str:
        return hashlib.sha256(self.to_json().encode()).hexdigest()

    def kinds(self) -> Counter:
        return Counter(e.kind for e in self.events)

    def of_kind(self, kind: str) -> Tuple[ChaosEvent, ...]:
        return tuple(e for e in self.events if e.kind == kind)


def generate_chaos_schedule(
        seed: int, node_ids: Sequence[int], *,
        protected: Sequence[int] = (),
        kill_frac_range: Tuple[float, float] = (0.10, 0.30),
        rejoin_delay_frac_range: Tuple[float, float] = (0.15, 0.25),
        slowdown_start_range: Tuple[float, float] = (0.25, 0.40),
        slowdown_len_range: Tuple[float, float] = (0.20, 0.35),
        slowdown_factor_range: Tuple[float, float] = (6.0, 12.0),
        transfer_frac_range: Tuple[float, float] = (0.10, 0.60),
        n_transfer_faults: int = 1) -> ChaosSchedule:
    """Generate the canonical composed schedule: one kill -> rejoin cycle,
    one sustained slowdown window, `n_transfer_faults` transfer faults and
    one tool timeout. Pure over `np.random.RandomState(seed)` — the same
    arguments always yield the same schedule (and digest).

    `node_ids` are the fault-eligible nodes (typically the decode-capable
    fleet); `protected` nodes are never picked as kill/slowdown victims
    (e.g. the sole prefiller). At least two eligible victims are required so
    the kill victim and the slowdown victim differ — the fleet keeps a
    healthy decode path at every point of the schedule.
    """
    eligible = [n for n in node_ids if n not in set(protected)]
    if len(eligible) < 2:
        raise ValueError(
            f"need >= 2 fault-eligible nodes so the kill victim and the "
            f"slowdown victim differ (got eligible={eligible} from "
            f"node_ids={list(node_ids)}, protected={list(protected)})")
    rs = np.random.RandomState(seed)

    def u(lo_hi: Tuple[float, float]) -> float:
        return float(rs.uniform(*lo_hi))

    kill_victim, slow_victim = (
        int(x) for x in rs.choice(eligible, size=2, replace=False))
    kill_t = u(kill_frac_range)
    rejoin_t = kill_t + u(rejoin_delay_frac_range)
    slow_t = u(slowdown_start_range)
    slow_end_t = slow_t + u(slowdown_len_range)
    factor = u(slowdown_factor_range)
    events = [
        ChaosEvent(FAULT_KILL, kill_t, node_id=kill_victim),
        ChaosEvent(FAULT_REJOIN, rejoin_t, node_id=kill_victim),
        ChaosEvent(FAULT_SLOWDOWN, slow_t, node_id=slow_victim,
                   factor=factor),
        ChaosEvent(FAULT_SLOWDOWN_END, slow_end_t, node_id=slow_victim),
    ]
    for _ in range(n_transfer_faults):
        events.append(ChaosEvent(FAULT_TRANSFER, u(transfer_frac_range)))
    # tool timeouts mutate the workload pre-run; at_frac 0 keeps the sorted
    # order honest about when the fault takes effect
    events.append(ChaosEvent(FAULT_TOOL_TIMEOUT, 0.0,
                             conv_index=int(rs.randint(0, 1 << 16))))
    events.sort(key=lambda e: (e.at_frac, e.kind))
    return ChaosSchedule(seed=seed, events=tuple(events))
