"""Chaos-run invariants: an inline placement monitor plus the post-run
checker asserting the paper's robustness contract.

The monitor is a PURE event-bus subscriber — it reads `NodeState` exactly at
the moment the runtime publishes each admission event, so a placement on a
dead or quarantined node is caught at the instant it happens (with the
runtime's own loud guards as the second line of defense). It also keeps a
timestamped lifecycle log, which is both the evidence trail the checker
consumes and the availability timeline the chaos benchmark integrates.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.core.events import (EV_ADMISSION_ADMIT, EV_ADMISSION_PARK,
                               EV_NODE_FAILURE, EV_NODE_JOIN,
                               EV_NODE_QUARANTINE, ServeEvent)
from repro.core.signals import NODE_ACTIVE

from .schedule import (FAULT_KILL, FAULT_SLOWDOWN, FAULT_TOOL_TIMEOUT,
                       FAULT_TRANSFER, ChaosSchedule)


@dataclasses.dataclass
class LifecycleMoment:
    """One observed lifecycle transition: (logical time, event kind,
    node_id, payload)."""
    t: float
    kind: str
    node_id: int
    data: Dict[str, Any]


class PlacementMonitor:
    """Bus subscriber asserting zero placements on dead/quarantined nodes
    and recording the lifecycle evidence trail.

    * every `admission_park` / `admission_admit` target must be alive and
      ACTIVE at publish time (violations are recorded AND raised — a chaos
      run must fail loudly at the moment of the bad placement);
    * `node_failure` / `node_join` / `node_quarantine` moments append to
      `lifecycle_log` (ordered by logical time — the bus is synchronous);
    * admits landing on a node AFTER it was observed joining count toward
      `post_join_admits[node_id]` — the "serves again" evidence.
    """

    KINDS = (EV_ADMISSION_PARK, EV_ADMISSION_ADMIT, EV_NODE_FAILURE,
             EV_NODE_JOIN, EV_NODE_QUARANTINE)

    def __init__(self, runtime):
        self.runtime = runtime
        self.violations: List[str] = []
        self.lifecycle_log: List[LifecycleMoment] = []
        self.joins: List[LifecycleMoment] = []
        self.quarantines: List[LifecycleMoment] = []
        self.failures: List[LifecycleMoment] = []
        self.post_join_admits: Dict[int, int] = {}
        self._joined_nodes: set = set()
        self.n_admissions = 0
        self._unsub = runtime.bus.subscribe(self._on_event, kinds=self.KINDS)

    def close(self):
        self._unsub()

    def _on_event(self, ev: ServeEvent):
        if ev.kind in (EV_ADMISSION_PARK, EV_ADMISSION_ADMIT):
            self.n_admissions += 1
            st = self.runtime.view.node(ev.node_id)
            if not st.alive or st.lifecycle != NODE_ACTIVE:
                msg = (f"t={ev.t:.3f} {ev.kind} for cid {ev.cid} targeted "
                       f"node {ev.node_id} which is "
                       f"{'dead' if not st.alive else st.lifecycle}")
                self.violations.append(msg)
                raise AssertionError(msg)
            if ev.kind == EV_ADMISSION_ADMIT \
                    and ev.node_id in self._joined_nodes:
                self.post_join_admits[ev.node_id] = \
                    self.post_join_admits.get(ev.node_id, 0) + 1
            return
        m = LifecycleMoment(t=ev.t, kind=ev.kind, node_id=ev.node_id,
                            data=dict(ev.data))
        self.lifecycle_log.append(m)
        if ev.kind == EV_NODE_JOIN:
            self.joins.append(m)
            self._joined_nodes.add(ev.node_id)
        elif ev.kind == EV_NODE_QUARANTINE:
            self.quarantines.append(m)
        elif ev.kind == EV_NODE_FAILURE:
            self.failures.append(m)

    # ----- derived metrics ---------------------------------------------------
    def availability_timeline(self, node_ids, t0: float, t1: float
                              ) -> Dict[int, float]:
        """Fraction of [t0, t1] each node spent schedulable (alive AND
        ACTIVE), integrated from the observed lifecycle log. Nodes are
        assumed schedulable at t0 (chaos runs start on a healthy fleet)."""
        out: Dict[int, float] = {}
        span = max(t1 - t0, 1e-9)
        for nid in node_ids:
            moments = [m for m in self.lifecycle_log if m.node_id == nid
                       and t0 <= m.t <= t1]
            up, t_prev, is_up = 0.0, t0, True
            for m in moments:
                if is_up:
                    up += m.t - t_prev
                t_prev = m.t
                is_up = m.kind == EV_NODE_JOIN
            if is_up:
                up += t1 - t_prev
            out[nid] = min(1.0, max(0.0, up / span))
        return out

    def recovery_latencies(self) -> List[float]:
        """Observed dead-interval lengths: failure -> from_dead join, per
        node, in logical seconds."""
        out: List[float] = []
        down_at: Dict[int, float] = {}
        for m in self.lifecycle_log:
            if m.kind == EV_NODE_FAILURE:
                down_at[m.node_id] = m.t
            elif (m.kind == EV_NODE_JOIN
                  and m.data.get("reason") == "from_dead"
                  and m.node_id in down_at):
                out.append(m.t - down_at.pop(m.node_id))
        return out


def check_chaos_invariants(
        records: list, gateway, monitor: PlacementMonitor,
        schedule: ChaosSchedule, convs: list,
        baseline_streams: Dict[Tuple[int, int], Any], *,
        streams: Optional[Dict[Tuple[int, int], Any]] = None,
        require_quarantine: bool = True) -> Dict[str, Any]:
    """Assert the chaos contract on a finished run; returns the evidence
    summary on success, raises `AssertionError` naming the first broken
    invariant otherwise.

    1. COMPLETION — every submitted conversation finished.
    2. STREAM IDENTITY — every per-(cid, turn) stream the gateway
       accumulated is byte-identical to the fault-free baseline
       (`streams` overrides the accumulation compared — the simulator
       backend normalizes its per-turn count lists to totals first).
    3. PLACEMENT — the monitor observed zero placements on dead or
       quarantined nodes.
    4. EVIDENCE — each fault kind in the schedule left its observable
       trace: kill -> a failure AND a from_dead join on the same node;
       slowdown -> a quarantine AND a from_quarantine join AND at least
       one post-join admit somewhere (the rejoined fleet serves again);
       transfer faults / tool timeouts -> runtime retry / eviction
       counters advanced.
    """
    done_cids = {r.cid for r in records}
    want_cids = {c.cid for c in convs}
    missing = sorted(want_cids - done_cids)
    assert not missing, f"conversations never completed: {missing}"

    got_streams = gateway.streams if streams is None else streams
    assert got_streams == baseline_streams, (
        "per-(cid, turn) streams diverged from the fault-free baseline: "
        + _describe_stream_diff(got_streams, baseline_streams))

    assert not monitor.violations, (
        f"placements on dead/quarantined nodes: {monitor.violations}")

    kinds = schedule.kinds()
    evidence: Dict[str, Any] = {
        "n_failures": len(monitor.failures),
        "n_joins": len(monitor.joins),
        "n_quarantines": len(monitor.quarantines),
        "post_join_admits": dict(monitor.post_join_admits),
        "recovery_latencies_s": monitor.recovery_latencies(),
    }
    if kinds.get(FAULT_KILL):
        assert monitor.failures, "schedule kills a node but no node_failure"
        dead_joined = {m.node_id for m in monitor.joins
                       if m.data.get("reason") == "from_dead"}
        killed = {e.node_id for e in schedule.of_kind(FAULT_KILL)}
        assert killed <= dead_joined, (
            f"killed nodes {sorted(killed)} but only {sorted(dead_joined)} "
            f"rejoined from dead")
    if kinds.get(FAULT_SLOWDOWN) and require_quarantine:
        assert monitor.quarantines, (
            "schedule slows a node but no quarantine was observed — the "
            "observed-TBT trigger never tripped (tune factor/window)")
        q_nodes = {m.node_id for m in monitor.quarantines}
        rq_nodes = {m.node_id for m in monitor.joins
                    if m.data.get("reason") == "from_quarantine"}
        assert q_nodes <= rq_nodes, (
            f"quarantined nodes {sorted(q_nodes)} but only "
            f"{sorted(rq_nodes)} rejoined from quarantine")
        served_again = rq_nodes & set(monitor.post_join_admits)
        assert served_again, (
            f"no admission landed on a quarantine-rejoined node "
            f"({sorted(rq_nodes)}) after its join — the replica never "
            f"observably served again (post-join admits: "
            f"{dict(monitor.post_join_admits)})")
    if kinds.get(FAULT_TRANSFER):
        n_retries = getattr(gateway.runtime, "n_transfer_retries", 0)
        assert n_retries >= 1, (
            "schedule arms transfer faults but the runtime observed zero "
            "transfer retries")
        evidence["n_transfer_retries"] = n_retries
    if kinds.get(FAULT_TOOL_TIMEOUT):
        n_evict = getattr(gateway.runtime, "n_tool_evictions", 0)
        n_recovered = sum(1 for r in records if getattr(r, "recovered", False)
                          or getattr(r, "n_tool_evictions", 0) > 0)
        assert n_evict >= 1 or n_recovered >= 1, (
            "schedule inflates a tool latency past the deadline but no "
            "tool eviction/recovery was observed")
        evidence["n_tool_evictions"] = n_evict
    return evidence


def _describe_stream_diff(got: Dict, want: Dict) -> str:
    extra = sorted(set(got) - set(want))
    missing = sorted(set(want) - set(got))
    diff = sorted(k for k in set(got) & set(want) if got[k] != want[k])
    return (f"{len(diff)} mismatched keys (first: {diff[:3]}), "
            f"{len(missing)} missing (first: {missing[:3]}), "
            f"{len(extra)} extra (first: {extra[:3]})")
