"""Chaos driver: apply a `ChaosSchedule` to a live gateway-driven run.

The driver owns three jobs:

1. `apply_tool_timeouts` — materialize the schedule's tool-timeout faults
   as a MUTATED COPY of the workload (a victim conversation's mid-turn tool
   latency inflated past the deadline). The same mutated workload feeds the
   chaos run AND the fault-free baseline: tool latency never changes token
   content, so byte-identity still holds while the chaos run additionally
   exercises the watchdog-evict -> replay path.
2. `arm_schedule` — translate fraction-of-span fault times into logical
   seconds and arm each fault on the runtime's own event heap
   (`fail_replica` / `recover_replica` / `inject_slowdown` / `call_at`
   + `inject_transfer_faults`), so faults interleave deterministically with
   serving work.
3. `run_chaos` — drive the workload live through a `ServeGateway` with a
   `PlacementMonitor` attached, optionally holding back a second wave of
   conversations until a node has been OBSERVED rejoining — guaranteeing
   the run contains placements that exercise the rejoined replica.
"""
from __future__ import annotations

import asyncio
import copy
import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.conversation import Conversation
from repro.serve.client import GatewayClient
from repro.serve.gateway import ServeGateway

from .invariants import PlacementMonitor
from .schedule import (FAULT_KILL, FAULT_REJOIN, FAULT_SLOWDOWN,
                       FAULT_SLOWDOWN_END, FAULT_TOOL_TIMEOUT,
                       FAULT_TRANSFER, ChaosSchedule)


def apply_tool_timeouts(convs: List[Conversation],
                        schedule: ChaosSchedule,
                        tool_deadline_s: float) -> List[Conversation]:
    """Return a deep copy of `convs` with each tool-timeout fault applied:
    the victim's middle turn's `tool_time_s` is raised to >= 3x the
    deadline, so the runtime's watchdog MUST evict it and the eventual tool
    return MUST re-admit by journaled replay. Victim selection is
    deterministic: multi-turn conversations sorted by cid, indexed by the
    event's `conv_index` modulo their count."""
    out = copy.deepcopy(convs)
    eligible = sorted((c for c in out if c.n_turns >= 2),
                      key=lambda c: c.cid)
    for ev in schedule.of_kind(FAULT_TOOL_TIMEOUT):
        if not eligible:
            raise ValueError("tool-timeout fault scheduled but the workload "
                             "has no multi-turn conversation to victimize")
        victim = eligible[ev.conv_index % len(eligible)]
        mid = (victim.n_turns - 1) // 2  # a turn that HAS a tool wait after
        victim.turns[mid].tool_time_s = max(victim.turns[mid].tool_time_s,
                                            3.0 * tool_deadline_s)
    return out


def arm_schedule(runtime, schedule: ChaosSchedule, span_s: float,
                 t0: float = 0.0) -> None:
    """Arm every runtime-side fault on the runtime's event heap. Fault
    times are `t0 + at_frac * span_s` logical seconds. Tool-timeout events
    are workload-side (see `apply_tool_timeouts`) and skipped here."""
    for ev in schedule.events:
        t = t0 + ev.at_frac * span_s
        if ev.kind == FAULT_KILL:
            runtime.fail_replica(ev.node_id, t)
        elif ev.kind == FAULT_REJOIN:
            runtime.recover_replica(ev.node_id, t)
        elif ev.kind == FAULT_SLOWDOWN:
            runtime.inject_slowdown(ev.node_id, ev.factor, at_s=t)
        elif ev.kind == FAULT_SLOWDOWN_END:
            runtime.inject_slowdown(ev.node_id, 1.0, at_s=t)
        elif ev.kind == FAULT_TRANSFER:
            runtime.call_at(t, lambda n=ev.n: runtime.inject_transfer_faults(n))
        elif ev.kind == FAULT_TOOL_TIMEOUT:
            pass  # applied to the workload before submission


@dataclasses.dataclass
class ChaosRunResult:
    records: list
    gateway: ServeGateway
    client: GatewayClient
    monitor: PlacementMonitor

    @property
    def streams(self) -> Dict[Tuple[int, int], List[int]]:
        return self.gateway.streams


def run_chaos(runtime, convs: List[Conversation], schedule: ChaosSchedule,
              span_s: float, *,
              second_wave: Optional[List[Conversation]] = None,
              quarantine_wave: Optional[List[Conversation]] = None,
              shed_watermark: Optional[int] = None,
              stagger: int = 2, max_events_per_tick: int = 64,
              ticks_between: int = 8) -> ChaosRunResult:
    """Drive `convs` live through a gateway while `schedule`'s faults fire
    mid-flight. Modeled on `serve_scenario_live`, plus:

    * a `PlacementMonitor` subscribed BEFORE any event executes, so every
      placement of the run is checked against the lifecycle contract;
    * an optional `second_wave` staged only after the monitor observes ANY
      `node_join`, and an optional `quarantine_wave` staged only after a
      join with reason ``from_quarantine`` — those conversations'
      placements are guaranteed to see the rejoined node in the
      schedulable set (a cold rejoined node has zero resident KV, exactly
      what min-KV placement prefers), which is the "serves again"
      evidence the invariant checker demands. If a wave's trigger never
      fires it submits once the preceding work is done, so the run still
      completes (and the checker reports the missing evidence).

    The runtime must already have `schedule` armed (see `arm_schedule`) —
    the driver keeps arming and driving separate so offline (non-gateway)
    replays can arm the same schedule identically.
    """
    ordered = sorted(convs, key=lambda c: (c.arrival_s, c.cid))

    def _sorted(w):
        return sorted(w or [], key=lambda c: (c.arrival_s, c.cid))

    waves = [
        (lambda m: bool(m.joins), _sorted(second_wave)),
        (lambda m: any(j.data.get("reason") == "from_quarantine"
                       for j in m.joins), _sorted(quarantine_wave)),
    ]

    async def _run():
        gw = ServeGateway(runtime, shed_watermark=shed_watermark,
                          max_events_per_tick=max_events_per_tick)
        monitor = PlacementMonitor(runtime)
        client = GatewayClient(gw)
        gw.start()
        all_convs = ordered + [c for _, w in waves for c in w]
        consumers = [asyncio.ensure_future(client.collect(c.cid))
                     for c in all_convs]
        for i in range(0, len(ordered), max(stagger, 1)):
            gw.submit(ordered[i:i + max(stagger, 1)])
            for _ in range(ticks_between):
                await asyncio.sleep(0)
        submitted = len(ordered)
        for trigger, wave in waves:
            while wave:
                # liveness fallback: everything already submitted ran dry
                # without the trigger firing — submit anyway so the run
                # completes (the evidence check reports what was missing)
                if trigger(monitor) or len(gw.done_cids) >= submitted:
                    gw.submit(wave)
                    submitted += len(wave)
                    wave = []
                    break
                await asyncio.sleep(0)
        records = await gw.drain()
        await asyncio.gather(*consumers)
        monitor.close()
        return ChaosRunResult(records=records, gateway=gw, client=client,
                              monitor=monitor)

    return asyncio.run(_run())
