"""Seeded chaos harness for the serving runtimes: byte-identical fault
schedules (`schedule`), a live gateway driver that arms and applies them
mid-flight (`driver`), and the invariant monitor/checker asserting the
robustness contract — completion, stream byte-identity vs the fault-free
baseline, zero placements on dead or quarantined nodes (`invariants`)."""
from .driver import (ChaosRunResult, apply_tool_timeouts, arm_schedule,
                     run_chaos)
from .invariants import (LifecycleMoment, PlacementMonitor,
                         check_chaos_invariants)
from .schedule import (FAULT_KILL, FAULT_KINDS, FAULT_REJOIN, FAULT_SLOWDOWN,
                       FAULT_SLOWDOWN_END, FAULT_TOOL_TIMEOUT, FAULT_TRANSFER,
                       ChaosEvent, ChaosSchedule, generate_chaos_schedule)

__all__ = [
    "ChaosEvent", "ChaosSchedule", "generate_chaos_schedule",
    "FAULT_KILL", "FAULT_REJOIN", "FAULT_SLOWDOWN", "FAULT_SLOWDOWN_END",
    "FAULT_TRANSFER", "FAULT_TOOL_TIMEOUT", "FAULT_KINDS",
    "apply_tool_timeouts", "arm_schedule", "run_chaos", "ChaosRunResult",
    "PlacementMonitor", "LifecycleMoment", "check_chaos_invariants",
]
