"""Model configuration for every architecture family the framework serves.

A single frozen dataclass describes dense transformers, GQA/MLA attention,
MoE, SSM (RWKV6), hybrid (RG-LRU + local attention), encoder-decoder, and
stub-frontend (audio/vlm) models. `repro/configs/<arch>.py` instantiates one
per assigned architecture.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

# Layer kinds used in `block_pattern`.
ATTN_GLOBAL = "attn_global"
ATTN_LOCAL = "attn_local"
ATTN_MLA = "attn_mla"
RGLRU = "rglru"
RWKV6 = "rwkv6"

FAMILIES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0  # 0 -> d_model // n_heads
    activation: str = "silu"  # silu | gelu | squared_relu
    norm: str = "rmsnorm"  # rmsnorm | layernorm | nonparametric_ln
    gated_mlp: bool = True  # SwiGLU-style gate

    # Attention layout. `block_pattern` is a repeating per-layer pattern; the
    # model tiles it across n_layers (remainder layers take pattern[:rem]).
    block_pattern: Tuple[str, ...] = (ATTN_GLOBAL,)
    window: int = 0  # local-attention window (tokens)
    rope_theta: float = 10_000.0
    rope_theta_local: float = 10_000.0  # theta for sliding-window layers
    qk_norm: bool = False

    # MoE (0 experts -> dense MLP everywhere).
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0  # per-expert hidden dim; 0 -> d_ff
    capacity_factor: float = 1.25
    moe_every: int = 1  # MoE layer every k-th layer (1 = all layers)

    # MLA (DeepSeek-style) — active when kv_lora_rank > 0.
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # Recurrent families.
    rwkv_head_size: int = 64
    lru_width: int = 0  # 0 -> d_model
    conv1d_width: int = 4  # RG-LRU temporal conv width

    # Encoder-decoder (whisper).
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500  # whisper 30s @ 50Hz after conv stub

    # Stub frontend: "none" | "audio" | "vision". Frontend embeddings are
    # provided precomputed via input_specs (the stub), shape (B, F, d_model).
    frontend: str = "none"
    frontend_len: int = 0

    max_seq: int = 131_072
    dtype: str = "bfloat16"
    tie_embeddings: bool = False

    # ----- performance variants (§Perf hillclimbs; defaults = paper-faithful
    # baseline) -----------------------------------------------------------
    # custom-VJP flash attention: backward recomputes attention instead of
    # letting scan save per-chunk online-softmax carriers (train memory).
    flash_vjp: bool = False
    # quantized KV cache for the decode tail ("" = same as dtype).
    kv_cache_dtype: str = ""
    kv_quant_scale: float = 0.05
    # pad RWKV heads so the head axis TP-shards without resharding
    # collectives (e.g. 40 heads -> 48 under 16-way TP).
    rwkv_pad_heads_to: int = 0
    # measurement-mode flags (depth probes): Python-unroll the layer scan and
    # run attention as one full block so XLA cost analysis counts every FLOP
    # (its loop bodies are otherwise counted once; see benchmarks/roofline.py)
    unroll_layers: bool = False
    attn_block_full: bool = False
    # remat granularity for training: "group" (paper-faithful baseline,
    # checkpoints at layer-scan boundaries) or "layer" (checkpoint every
    # block — backward holds one layer's activations, not a whole group's).
    remat_granularity: str = "group"

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.d_expert == 0 and self.n_experts:
            object.__setattr__(self, "d_expert", self.d_ff)
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)

    # ----- derived properties -------------------------------------------------
    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def padded_vocab(self) -> int:
        """Embedding-table vocab padded to a multiple of 256 so the vocab dim
        shards evenly under 16-way TP (and stays MXU-aligned). Logits beyond
        vocab_size are padding; the engine masks them at sampling."""
        return -(-self.vocab_size // 256) * 256

    @property
    def attention_free(self) -> bool:
        return all(k in (RWKV6, RGLRU) for k in self.block_pattern)

    @property
    def uses_mla(self) -> bool:
        return self.kv_lora_rank > 0

    @property
    def sub_quadratic(self) -> bool:
        """True when decode-side state does not grow linearly *unboundedly*
        with context for the majority of layers (SSM / hybrid / mostly-local
        attention). Governs long_500k eligibility."""
        kinds = self.layer_kinds()
        n_full = sum(1 for k in kinds if k in (ATTN_GLOBAL, ATTN_MLA))
        return n_full == 0 or (self.window > 0 and n_full <= len(kinds) // 4)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind sequence, tiling block_pattern across n_layers."""
        pat = self.block_pattern
        reps = -(-self.n_layers // len(pat))
        return tuple((pat * reps)[: self.n_layers])

    def pattern_groups(self) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
        """(pattern, n_full_groups, remainder_kinds) for grouped layer scan."""
        pat = self.block_pattern
        n_groups = self.n_layers // len(pat)
        rem = tuple(pat[: self.n_layers - n_groups * len(pat)])
        return pat, n_groups, rem

    # ----- KV/state bookkeeping ------------------------------------------------
    def kv_bytes_per_token(self) -> int:
        """Bytes of decoder-side cache state appended per token (all layers).
        Used by the serving engine's occupancy signal and provisioning."""
        itemsize = jnp.dtype(self.dtype).itemsize
        total = 0
        for kind in self.layer_kinds():
            if kind == ATTN_GLOBAL:
                total += 2 * self.n_kv_heads * self.head_dim * itemsize
            elif kind == ATTN_LOCAL:
                # Windowed cache amortizes to 0 growth once full; count 0 here
                # (bounded state accounted in state_bytes_fixed).
                total += 0
            elif kind == ATTN_MLA:
                total += (self.kv_lora_rank + self.qk_rope_dim) * itemsize
            # rwkv6 / rglru carry O(1) state -> 0 growth
        return total

    def state_bytes_fixed(self) -> int:
        """Per-conversation state that does NOT grow with context."""
        itemsize = jnp.dtype(self.dtype).itemsize
        total = 0
        for kind in self.layer_kinds():
            if kind == ATTN_LOCAL:
                total += 2 * self.window * self.n_kv_heads * self.head_dim * itemsize
            elif kind == RWKV6:
                n_heads = self.d_model // self.rwkv_head_size
                total += n_heads * self.rwkv_head_size ** 2 * 4  # fp32 state
                total += 2 * self.d_model * itemsize  # token-shift
            elif kind == RGLRU:
                total += self.lru_width * 4
                total += self.conv1d_width * self.lru_width * itemsize
        return total

    def param_count(self) -> int:
        """Analytical parameter count (matches init_params within ties)."""
        d, hd = self.d_model, self.head_dim
        n = 0
        n += self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += d * self.vocab_size
        for kind in self.layer_kinds():
            n += 2 * d  # two norms (rmsnorm scales); nonparam LN contributes ~0
            if kind in (ATTN_GLOBAL, ATTN_LOCAL):
                n += d * self.n_heads * hd  # wq
                n += 2 * d * self.n_kv_heads * hd  # wk, wv
                n += self.n_heads * hd * d  # wo
            elif kind == ATTN_MLA:
                qd = self.qk_nope_dim + self.qk_rope_dim
                if self.q_lora_rank:
                    n += d * self.q_lora_rank + self.q_lora_rank * self.n_heads * qd
                else:
                    n += d * self.n_heads * qd
                n += d * (self.kv_lora_rank + self.qk_rope_dim)
                n += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                n += self.n_heads * self.v_head_dim * d
            elif kind == RWKV6:
                n += 4 * d * d + 2 * d * d  # r,k,v,o,g + decay/bonus approx
            elif kind == RGLRU:
                w = self.lru_width
                n += 2 * d * w + w * d + 2 * w + self.conv1d_width * w
            # MLP / MoE
            if self.n_experts and kind not in (RWKV6,):
                fe = self.d_expert
                n += d * self.n_experts  # router
                mul = 3 if self.gated_mlp else 2
                n += self.n_experts * mul * d * fe
                n += self.n_shared_experts * mul * d * self.d_ff
            else:
                mul = 3 if self.gated_mlp else 2
                n += mul * d * self.d_ff
        if self.is_encoder_decoder:
            for _ in range(self.n_encoder_layers):
                n += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
                mul = 3 if self.gated_mlp else 2
                n += mul * d * self.d_ff + 2 * d
            # decoder cross-attention (one per decoder layer)
            n += self.n_layers * (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                                  + self.n_heads * hd * d + d)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k experts only)."""
        if not self.n_experts:
            return self.param_count()
        d, fe = self.d_model, self.d_expert
        mul = 3 if self.gated_mlp else 2
        per_layer_all = self.n_experts * mul * d * fe
        per_layer_active = self.top_k * mul * d * fe
        n_moe_layers = sum(1 for i, k in enumerate(self.layer_kinds())
                           if k != RWKV6 and (i % self.moe_every == 0))
        return self.param_count() - n_moe_layers * (per_layer_all - per_layer_active)

    def scaled(self, **overrides) -> "ModelConfig":
        """Return a reduced copy (used for smoke tests / CPU engine runs)."""
        return dataclasses.replace(self, **overrides)


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving reduction for CPU smoke tests: same layer kinds and
    code paths, tiny dims."""
    pat = cfg.block_pattern
    # keep at least one full pattern repetition (plus remainder behaviour)
    n_layers = max(len(pat), 2)
    kw = dict(
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else 0,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        max_seq=512,
        window=min(cfg.window, 64) if cfg.window else 0,
        frontend_len=min(cfg.frontend_len, 8) if cfg.frontend_len else 0,
        encoder_seq=16,
        n_encoder_layers=2 if cfg.is_encoder_decoder else 0,
        dtype="float32",
    )
    if cfg.n_experts:
        # dropless capacity (cf = E/K) so prefill/decode token grouping cannot
        # change results via capacity drops — keeps consistency tests exact.
        top_k = min(cfg.top_k, 2)
        kw.update(n_experts=4, top_k=top_k, d_expert=32,
                  n_shared_experts=min(cfg.n_shared_experts, 1),
                  capacity_factor=4.0 / top_k)
    if cfg.uses_mla:
        kw.update(kv_lora_rank=32, q_lora_rank=0, qk_nope_dim=16,
                  qk_rope_dim=8, v_head_dim=16)
    if RWKV6 in pat:
        kw.update(rwkv_head_size=16)
    if RGLRU in pat:
        kw.update(lru_width=64)
    return cfg.scaled(**kw)
