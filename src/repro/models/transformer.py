"""Decoder-only LM assembled from a ModelConfig: scan over layer *pattern
groups* (HLO size ~O(1) in depth), optional stub frontend (VLM), remat in
train mode, and the prefill / decode entry points the serving engine uses."""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .blocks import block_cache_skeleton, block_decode, block_prefill, block_skeleton
from .config import ModelConfig
from .layers import (apply_norm, embed, embed_skeleton, norm_skeleton, sds,
                     unembed, unembed_skeleton)


def _stack_skeleton(sk, n: int):
    return jax.tree_util.tree_map(
        lambda l: sds((n, *l.shape), l.dtype), sk)


def lm_skeleton(cfg: ModelConfig) -> Dict[str, Any]:
    pat, n_groups, rem = cfg.pattern_groups()
    sk: Dict[str, Any] = {
        "embed": embed_skeleton(cfg),
        "final_norm": norm_skeleton(cfg),
        "unembed": unembed_skeleton(cfg),
    }
    if n_groups:
        gsk = {f"p{i}": block_skeleton(cfg, kind) for i, kind in enumerate(pat)}
        sk["groups"] = _stack_skeleton(gsk, n_groups)
    if rem:
        sk["rem"] = {f"p{i}": block_skeleton(cfg, kind)
                     for i, kind in enumerate(rem)}
    return sk


def lm_cache_skeleton(cfg: ModelConfig, batch: int, ctx: int) -> Dict[str, Any]:
    pat, n_groups, rem = cfg.pattern_groups()
    ck: Dict[str, Any] = {}
    if n_groups:
        gck = {f"p{i}": block_cache_skeleton(cfg, kind, batch, ctx)
               for i, kind in enumerate(pat)}
        ck["groups"] = _stack_skeleton(gck, n_groups)
    if rem:
        ck["rem"] = {f"p{i}": block_cache_skeleton(cfg, kind, batch, ctx)
                     for i, kind in enumerate(rem)}
    return ck


def _embed_inputs(params, cfg: ModelConfig, tokens, frontend_embeds):
    h = embed(params["embed"], cfg, tokens).astype(cfg.jnp_dtype)
    n_front = 0
    if cfg.frontend != "none" and frontend_embeds is not None:
        fe = frontend_embeds.astype(cfg.jnp_dtype)
        h = jnp.concatenate([fe, h], axis=1)
        n_front = fe.shape[1]
    return h, n_front


def lm_hidden(params, cfg: ModelConfig, tokens, *, mode: str = "train",
              caches: Optional[Dict] = None, start_pos: int = 0,
              frontend_embeds=None, kv_lens=None, remat: bool = False,
              prefix_start=None, attention_impl: str = "xla"
              ) -> Tuple[jnp.ndarray, Dict]:
    """Run the stack in 'train'/'prefill' mode. Returns (hidden, caches_out).
    hidden is post-final-norm (B, S[, +frontend], D); caller unembeds
    (train uses chunked-vocab loss instead of materializing logits).
    `attention_impl` (static) selects the prefill attention kernel for
    global-attention blocks (see gqa_prefill); the train path keeps the
    default jnp attention."""
    pat, n_groups, rem = cfg.pattern_groups()
    h, n_front = _embed_inputs(params, cfg, tokens, frontend_embeds)
    sp = start_pos  # frontend tokens occupy the first positions

    def one_block(kind, bparams, hh, bcache):
        return block_prefill(bparams, cfg, kind, hh, sp, cache=bcache,
                             kv_lens=kv_lens, prefix_start=prefix_start,
                             attention_impl=attention_impl)

    per_layer = remat and cfg.remat_granularity in ("layer", "both")
    block_fns = {kind: (jax.checkpoint(partial(one_block, kind))
                        if per_layer else partial(one_block, kind))
                 for kind in set(pat)}

    train_mode = mode == "train"

    def group_fn(hc, xs):
        gparams, gcache = xs
        hh = hc
        outs = {}
        for i, kind in enumerate(pat):
            key = f"p{i}"
            hh, co = block_fns[kind](
                gparams[key], hh,
                None if gcache is None else gcache[key])
            if not train_mode:
                outs[key] = co
        return hh, outs

    outer = remat and cfg.remat_granularity in ("group", "both")
    body = jax.checkpoint(group_fn) if outer else group_fn
    caches_out: Dict[str, Any] = {}
    if n_groups:
        gcaches = None if caches is None else caches["groups"]
        if cfg.unroll_layers:
            outs = []
            for gi in range(n_groups):
                gp = jax.tree_util.tree_map(lambda l: l[gi], params["groups"])
                gc = None if gcaches is None else jax.tree_util.tree_map(
                    lambda l: l[gi], gcaches)
                h, o = body(h, (gp, gc))
                outs.append(o)
            gouts = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
        elif gcaches is None:
            # scan can't carry a None xs leaf; close over it instead
            def body_nocache(hc, gparams):
                return body(hc, (gparams, None))
            h, gouts = jax.lax.scan(body_nocache, h, params["groups"])
        else:
            h, gouts = jax.lax.scan(lambda hc, x: body(hc, x), h,
                                    (params["groups"], gcaches))
        caches_out["groups"] = gouts
    if rem:
        routs = {}
        for i, kind in enumerate(rem):
            key = f"p{i}"
            rc = None if caches is None else caches["rem"][key]
            h, co = block_prefill(params["rem"][key], cfg, kind, h, sp,
                                  cache=rc, kv_lens=kv_lens,
                                  prefix_start=prefix_start,
                                  attention_impl=attention_impl)
            if not train_mode:
                routs[key] = co
        caches_out["rem"] = routs
    h = apply_norm(params["final_norm"], cfg, h)
    if n_front:
        h = h[:, n_front:]
    return h, caches_out


def lm_logits(params, cfg: ModelConfig, hidden):
    return unembed(params.get("unembed", {}), params["embed"], cfg, hidden)


def lm_prefill(params, cfg: ModelConfig, tokens, *, caches=None,
               start_pos: int = 0, frontend_embeds=None, kv_lens=None,
               prefix_start=None, logits_at=None, attention_impl: str = "xla"):
    """Prefill: returns (logits (B,V), caches_out). logits_at selects the
    position whose logits are returned (engine passes true_len-1 when the
    token batch is right-padded to a bucket; default: last position).
    `attention_impl` (static) selects the prefill attention kernel."""
    h, caches_out = lm_hidden(params, cfg, tokens, mode="prefill",
                              caches=caches, start_pos=start_pos,
                              frontend_embeds=frontend_embeds, kv_lens=kv_lens,
                              prefix_start=prefix_start,
                              attention_impl=attention_impl)
    if logits_at is None:
        hh = h[:, -1]
    else:
        idx = jnp.asarray(logits_at, jnp.int32)
        if idx.ndim == 0:
            hh = jax.lax.dynamic_index_in_dim(h, idx, axis=1, keepdims=False)
        else:  # per-sequence gather
            hh = jnp.take_along_axis(
                h, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    return lm_logits(params, cfg, hh), caches_out


def lm_decode(params, cfg: ModelConfig, token, caches, position,
              kv_lens=None, ctx_limit=None, attention_impl: str = "xla"):
    """One decode step. token: (B,) int32; caches as from lm_cache_skeleton.
    Returns (logits (B,V), cache_updates) — attention updates are the new
    token's KV entries only (DESIGN.md §5). `ctx_limit` (static int) is an
    upper bound on kv_lens used to trim attention cache reads;
    `attention_impl` (static) selects the GQA decode attention kernel."""
    pat, n_groups, rem = cfg.pattern_groups()
    h = embed(params["embed"], cfg, token[:, None]).astype(cfg.jnp_dtype)

    updates: Dict[str, Any] = {}
    if n_groups:
        def group_fn(hc, xs):
            gparams, gcache = xs
            hh = hc
            outs = {}
            for i, kind in enumerate(pat):
                key = f"p{i}"
                hh, up = block_decode(gparams[key], cfg, kind, hh, position,
                                      gcache[key], kv_lens=kv_lens,
                                      ctx_limit=ctx_limit,
                                      attention_impl=attention_impl)
                outs[key] = up
            return hh, outs

        if cfg.unroll_layers:
            outs = []
            for gi in range(n_groups):
                gp = jax.tree_util.tree_map(lambda l: l[gi], params["groups"])
                gc = jax.tree_util.tree_map(lambda l: l[gi], caches["groups"])
                h, o = group_fn(h, (gp, gc))
                outs.append(o)
            gups = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
        else:
            h, gups = jax.lax.scan(group_fn, h,
                                   (params["groups"], caches["groups"]))
        updates["groups"] = gups
    if rem:
        rups = {}
        for i, kind in enumerate(rem):
            key = f"p{i}"
            h, up = block_decode(params["rem"][key], cfg, kind, h, position,
                                 caches["rem"][key], kv_lens=kv_lens,
                                 ctx_limit=ctx_limit,
                                 attention_impl=attention_impl)
            rups[key] = up
        updates["rem"] = rups
    h = apply_norm(params["final_norm"], cfg, h)
    return lm_logits(params, cfg, h[:, 0]), updates
