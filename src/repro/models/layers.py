"""Shared primitive layers: norms, activations, MLPs, RoPE, embeddings.

Everything is a pure function over explicit param pytrees. Param *skeletons*
(pytrees of jax.ShapeDtypeStruct) are the single source of truth for shapes;
`init_params` materializes them with deterministic per-leaf PRNG streams.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
# Skeleton / init plumbing
# --------------------------------------------------------------------------- #
def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), jnp.dtype(dtype))


def init_params(skeleton, key) -> Params:
    """Materialize a skeleton with fan-in-scaled normal init.

    Each leaf gets an independent stream derived from the hash of its tree
    path, so adding/removing params never reshuffles other leaves (important
    for checkpoint-compatible config evolution)."""
    leaves = jax.tree_util.tree_leaves_with_path(skeleton)

    def one(path, leaf):
        path_str = jax.tree_util.keystr(path)
        k = jax.random.fold_in(key, abs(hash(path_str)) % (2**31))
        name = path_str.rsplit("'", 2)[-2] if "'" in path_str else path_str
        if leaf.ndim == 0:
            return jnp.zeros((), leaf.dtype)
        if name.startswith(("ln", "norm", "scale")) or name.endswith("scale"):
            return jnp.ones(leaf.shape, leaf.dtype)
        if name in ("bias", "b") or name.endswith("_bias"):
            return jnp.zeros(leaf.shape, leaf.dtype)
        fan_in = leaf.shape[-2] if leaf.ndim >= 2 else leaf.shape[-1]
        std = 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, leaf.shape, jnp.float32) * std).astype(leaf.dtype)

    flat = [one(p, l) for p, l in leaves]
    treedef = jax.tree_util.tree_structure(skeleton)
    return jax.tree_util.tree_unflatten(treedef, flat)


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #
def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x, scale, bias=None, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        x = x * scale.astype(jnp.float32)
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dt)


def nonparametric_ln(x, eps: float = 1e-5):
    """OLMo-style LayerNorm without learnable scale/bias."""
    return layernorm(x, None, None, eps)


def norm_skeleton(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "nonparametric_ln":
        return {}  # no params
    return {"scale": sds((d,), cfg.dtype)}


def apply_norm(params, cfg, x):
    if cfg.norm == "nonparametric_ln":
        return nonparametric_ln(x)
    if cfg.norm == "layernorm":
        return layernorm(x, params["scale"])
    return rmsnorm(x, params["scale"])


# --------------------------------------------------------------------------- #
# Activations / MLP
# --------------------------------------------------------------------------- #
def activation(cfg, x):
    if cfg.activation == "gelu":
        return jax.nn.gelu(x)
    if cfg.activation == "squared_relu":
        r = jax.nn.relu(x)
        return r * r
    return jax.nn.silu(x)


def mlp_skeleton(cfg, d_in=None, d_ff=None):
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    sk = {"wi": sds((d, f), cfg.dtype), "wo": sds((f, d), cfg.dtype)}
    if cfg.gated_mlp:
        sk["wg"] = sds((d, f), cfg.dtype)
    return sk


def apply_mlp(params, cfg, x):
    h = x @ params["wi"]
    if cfg.gated_mlp:
        h = activation(cfg, x @ params["wg"]) * h
    else:
        h = activation(cfg, h)
    return h @ params["wo"]


# --------------------------------------------------------------------------- #
# Rotary embeddings
# --------------------------------------------------------------------------- #
def rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D) or (B, S, D); positions: (S,) int32."""
    dim = x.shape[-1]
    inv = rope_freqs(dim, theta)  # (D/2,)
    ang = positions.astype(jnp.float32)[:, None] * inv  # (S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == 4:  # head axis present: (S, 1, D/2) broadcasts over B, H
        cos, sin = cos[:, None, :], sin[:, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int, dtype):
    """Whisper-style fixed sinusoidal embeddings (S, D)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    inv = jnp.exp(-jnp.arange(0, dim, 2, dtype=jnp.float32) / dim * math.log(10000.0))
    ang = pos * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# --------------------------------------------------------------------------- #
# Embedding / unembedding
# --------------------------------------------------------------------------- #
def embed_skeleton(cfg):
    sk = {"w": sds((cfg.padded_vocab, cfg.d_model), cfg.dtype)}
    return sk


def embed(params, cfg, tokens):
    return jnp.take(params["w"], tokens, axis=0) * math.sqrt(cfg.d_model)


def unembed_skeleton(cfg):
    if cfg.tie_embeddings:
        return {}
    return {"w": sds((cfg.d_model, cfg.padded_vocab), cfg.dtype)}


def unembed(params, embed_params, cfg, h):
    if cfg.tie_embeddings:
        return h @ embed_params["w"].T
    return h @ params["w"]
