"""Per-layer blocks: (norm -> sequence mixer -> residual) + (norm -> FFN ->
residual), specialized by layer kind. One function pair (skeleton/apply) keyed
by kind keeps the grouped layer-scan in transformer.py homogeneous."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp

from .attention import (attn_skeleton, gqa_decode, gqa_prefill, mla_decode,
                        mla_prefill)
from .config import (ATTN_GLOBAL, ATTN_LOCAL, ATTN_MLA, RGLRU, RWKV6,
                     ModelConfig)
from .layers import apply_mlp, apply_norm, mlp_skeleton, norm_skeleton, sds
from .moe import apply_moe, moe_skeleton
from .recurrent import (rglru_decode, rglru_init_state, rglru_prefill,
                        rglru_skeleton, rwkv6_decode, rwkv6_init_state,
                        rwkv6_prefill, rwkv6_skeleton, rwkv_cmix,
                        rwkv_cmix_skeleton)

ATTN_KINDS = (ATTN_GLOBAL, ATTN_LOCAL, ATTN_MLA)


def block_skeleton(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    sk: Dict[str, Any] = {"ln1": norm_skeleton(cfg), "ln2": norm_skeleton(cfg)}
    if kind in ATTN_KINDS:
        sk["attn"] = attn_skeleton(cfg, kind)
    elif kind == RWKV6:
        sk["tmix"] = rwkv6_skeleton(cfg)
    elif kind == RGLRU:
        sk["rglru"] = rglru_skeleton(cfg)
    else:
        raise ValueError(kind)
    if kind == RWKV6:
        sk["cmix"] = rwkv_cmix_skeleton(cfg)
    elif cfg.n_experts:
        sk["moe"] = moe_skeleton(cfg)
    else:
        sk["mlp"] = mlp_skeleton(cfg)
    return sk


def block_cache_skeleton(cfg: ModelConfig, kind: str, batch: int,
                         ctx: int) -> Dict[str, Any]:
    """Shape skeleton of the decode-time cache one layer of `kind` holds after
    `ctx` tokens. Attention caches grow; recurrent states are fixed-size."""
    hd = cfg.head_dim
    cdt = cfg.kv_cache_dtype or cfg.dtype
    if kind == ATTN_GLOBAL:
        return {"k": sds((batch, ctx, cfg.n_kv_heads, hd), cdt),
                "v": sds((batch, ctx, cfg.n_kv_heads, hd), cdt)}
    if kind == ATTN_LOCAL:
        w = min(ctx, cfg.window) if cfg.window else ctx
        return {"k": sds((batch, w, cfg.n_kv_heads, hd), cdt),
                "v": sds((batch, w, cfg.n_kv_heads, hd), cdt)}
    if kind == ATTN_MLA:
        return {"ckv": sds((batch, ctx, cfg.kv_lora_rank), cdt),
                "krope": sds((batch, ctx, cfg.qk_rope_dim), cdt)}
    if kind == RWKV6:
        hs = cfg.rwkv_head_size
        nh_pad = cfg.rwkv_pad_heads_to or (cfg.d_model // hs)
        return {"s": sds((batch, nh_pad, hs, hs), "float32"),
                "shift": sds((batch, 1, cfg.d_model), cfg.dtype),
                "cshift": sds((batch, 1, cfg.d_model), cfg.dtype)}
    if kind == RGLRU:
        return {"h": sds((batch, cfg.lru_width), "float32"),
                "conv": sds((batch, cfg.conv1d_width - 1, cfg.lru_width),
                            cfg.dtype)}
    raise ValueError(kind)


GROWING_KEYS = ("k", "v", "ckv", "krope")


def is_growing(kind: str) -> bool:
    return kind in ATTN_KINDS


def _ffn(params, cfg: ModelConfig, kind: str, x, cache, updates):
    if kind == RWKV6:
        prev = cache["cshift"] if cache is not None else jnp.zeros(
            (x.shape[0], 1, x.shape[-1]), x.dtype)
        out, cshift = rwkv_cmix(params["cmix"], cfg, x, prev)
        updates["cshift"] = cshift
        return out
    if cfg.n_experts:
        return apply_moe(params["moe"], cfg, x)
    return apply_mlp(params["mlp"], cfg, x)


def block_prefill(params, cfg: ModelConfig, kind: str, x, start_pos,
                  cache: Optional[Dict] = None, kv_lens=None,
                  prefix_start=None, attention_impl: str = "xla"
                  ) -> Tuple[jnp.ndarray, Dict]:
    """cache: prefix KV (append-prefill) or recurrent state; None = fresh.
    Returns (x_out, cache_out): new-token KV entries for attention kinds,
    updated state for recurrent kinds (plus cmix shift under 'cshift').
    `attention_impl` (static) selects the prefill attention kernel for
    global-attention blocks; MLA, sliding-window and recurrent kinds have
    no Pallas prefill kernel and ignore it."""
    h = apply_norm(params["ln1"], cfg, x)
    updates: Dict[str, Any] = {}
    if kind == ATTN_MLA:
        out, cache_out = mla_prefill(params["attn"], cfg, h, start_pos,
                                     prefix_kv=cache, kv_lens=kv_lens,
                                     prefix_start=prefix_start)
    elif kind in (ATTN_GLOBAL, ATTN_LOCAL):
        out, cache_out = gqa_prefill(params["attn"], cfg, kind, h, start_pos,
                                     prefix_kv=cache, kv_lens=kv_lens,
                                     prefix_start=prefix_start,
                                     attention_impl=attention_impl)
    elif kind == RWKV6:
        state = cache or rwkv6_init_state(cfg, x.shape[0])
        out, cache_out = rwkv6_prefill(params["tmix"], cfg, h,
                                       {"s": state["s"], "shift": state["shift"]})
    elif kind == RGLRU:
        state = cache or rglru_init_state(cfg, x.shape[0])
        out, cache_out = rglru_prefill(params["rglru"], cfg, h, state)
    else:
        raise ValueError(kind)
    x = x + out
    h2 = apply_norm(params["ln2"], cfg, x)
    x = x + _ffn(params, cfg, kind, h2, cache, updates)
    cache_out = {**cache_out, **updates}
    return x, cache_out


def block_decode(params, cfg: ModelConfig, kind: str, x1, position,
                 cache: Dict, kv_lens=None, ctx_limit: Optional[int] = None,
                 attention_impl: str = "xla") -> Tuple[jnp.ndarray, Dict]:
    """x1: (B,1,D). Returns (x_out, cache_updates): for attention kinds the
    new token's KV entries (engine appends); for recurrent kinds the updated
    state. `ctx_limit` (static upper bound on kv_lens) trims attention cache
    reads; recurrent state is fixed-size and unaffected. `attention_impl`
    (static) selects the GQA decode attention kernel; MLA and recurrent
    kinds have no Pallas decode kernel and ignore it."""
    h = apply_norm(params["ln1"], cfg, x1)
    updates: Dict[str, Any] = {}
    if kind == ATTN_MLA:
        out, cache_out = mla_decode(params["attn"], cfg, h, position, cache,
                                    kv_lens=kv_lens, ctx_limit=ctx_limit)
    elif kind in (ATTN_GLOBAL, ATTN_LOCAL):
        out, cache_out = gqa_decode(params["attn"], cfg, kind, h, position,
                                    cache, kv_lens=kv_lens,
                                    ctx_limit=ctx_limit,
                                    attention_impl=attention_impl)
    elif kind == RWKV6:
        out, cache_out = rwkv6_decode(params["tmix"], cfg, h,
                                      {"s": cache["s"], "shift": cache["shift"]})
    elif kind == RGLRU:
        out, cache_out = rglru_decode(params["rglru"], cfg, h, cache)
    else:
        raise ValueError(kind)
    x1 = x1 + out
    h2 = apply_norm(params["ln2"], cfg, x1)
    x1 = x1 + _ffn(params, cfg, kind, h2, cache, updates)
    cache_out = {**cache_out, **updates}
    return x1, cache_out
