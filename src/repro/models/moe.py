"""Mixture-of-Experts with grouped-capacity gather/scatter dispatch.

TPU-native adaptation (DESIGN.md §5): instead of Switch-style dense dispatch
einsums — whose one-hot contractions dominate HLO FLOPs — tokens are routed
with integer gather/scatter inside fixed-size groups, and expert FFNs run as
one batched matmul over an (E, G·C, D) buffer. Experts shard over the
"model" mesh axis (expert parallelism); the only routing overhead is the
capacity padding (capacity_factor − 1) plus empty slots.

Tokens overflowing an expert's per-group capacity are dropped (standard
capacity-based MoE semantics); the residual path preserves their activations.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import activation, apply_mlp, mlp_skeleton, sds


def moe_skeleton(cfg: ModelConfig) -> Dict[str, Any]:
    d, fe, e = cfg.d_model, cfg.d_expert, cfg.n_experts
    sk = {
        "router": sds((d, e), "float32"),
        "wi": sds((e, d, fe), cfg.dtype),
        "wo": sds((e, fe, d), cfg.dtype),
    }
    if cfg.gated_mlp:
        sk["wg"] = sds((e, d, fe), cfg.dtype)
    if cfg.n_shared_experts:
        sk["shared"] = mlp_skeleton(cfg, d_ff=cfg.n_shared_experts * cfg.d_ff)
    return sk


def _group_tokens(x, group_size: int):
    """(B,S,D) -> (G,n,D) with n == group_size (pads the token axis)."""
    B, S, D = x.shape
    N = B * S
    flat = x.reshape(N, D)
    pad = (-N) % group_size
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    G = (N + pad) // group_size
    return flat.reshape(G, group_size, D), N, pad


def apply_moe(params, cfg: ModelConfig, x, group_size: int = 1024):
    """x: (B,S,D) -> (B,S,D)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    xg, N, pad = _group_tokens(x, min(group_size, B * S))
    G, n, _ = xg.shape
    cap = max(1, int(-(-n * K * cfg.capacity_factor // E)))

    logits = (xg.astype(jnp.float32) @ params["router"])  # (G,n,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)  # (G,n,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) within its expert's per-group queue
    onehot = jax.nn.one_hot(eidx, E, dtype=jnp.int32)  # (G,n,K,E)
    flat_oh = onehot.reshape(G, n * K, E)
    pos_flat = jnp.cumsum(flat_oh, axis=1) - flat_oh  # exclusive cumsum
    pos = (pos_flat.reshape(G, n, K, E) * onehot).sum(-1)  # (G,n,K)
    keep = pos < cap  # overflow tokens dropped

    # scatter token ids into (G, E, cap) slot table; empty slots -> n (pad row).
    # Dropped (over-capacity) writes are routed out-of-bounds and discarded
    # by mode="drop" so they can never clobber a live slot.
    slot_e = jnp.where(keep, eidx, E)
    slot_p = jnp.where(keep, pos, cap)
    token_of = jnp.broadcast_to(jnp.arange(n)[None, :, None], (G, n, K))
    table = jnp.full((G, E, cap), n, jnp.int32)  # n indexes a zero pad-token
    g_ix = jnp.broadcast_to(jnp.arange(G)[:, None, None], (G, n, K))
    table = table.at[g_ix, slot_e, slot_p].set(token_of, mode="drop")

    xg_pad = jnp.concatenate([xg, jnp.zeros((G, 1, D), xg.dtype)], axis=1)
    expert_in = xg_pad[g_ix_slots(G, E, cap), table]  # (G,E,cap,D)

    # batched expert FFN: (E, G*cap, D) x (E, D, Fe)
    ein = expert_in.transpose(1, 0, 2, 3).reshape(E, G * cap, D)
    h = jnp.einsum("emd,edf->emf", ein, params["wi"])
    if cfg.gated_mlp:
        h = activation(cfg, jnp.einsum("emd,edf->emf", ein, params["wg"])) * h
    else:
        h = activation(cfg, h)
    eout = jnp.einsum("emf,efd->emd", h, params["wo"])
    eout = eout.reshape(E, G, cap, D).transpose(1, 0, 2, 3)  # (G,E,cap,D)

    # gather back per (token, k) and combine with gate weights
    back = eout[g_ix, slot_e, slot_p]  # (G,n,K,D)
    back = back * (gate * keep).astype(back.dtype)[..., None]
    yg = back.sum(2)  # (G,n,D)

    y = yg.reshape(G * n, D)[:N].reshape(B, S, D)
    if cfg.n_shared_experts:
        y = y + apply_mlp(params["shared"], cfg, x)
    return y


def g_ix_slots(G, E, cap):
    return jnp.broadcast_to(jnp.arange(G)[:, None, None], (G, E, cap))


def moe_flops_per_token(cfg: ModelConfig) -> int:
    """Active matmul FLOPs per token through the MoE block (for roofline)."""
    mul = 3 if cfg.gated_mlp else 2
    f = 2 * mul * cfg.d_model * cfg.d_expert * cfg.top_k * cfg.capacity_factor
    f += 2 * cfg.d_model * cfg.n_experts  # router
    if cfg.n_shared_experts:
        f += 2 * mul * cfg.d_model * cfg.d_ff * cfg.n_shared_experts
    return int(f)
