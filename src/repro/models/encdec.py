"""Encoder-decoder model (Whisper backbone). The audio conv frontend is a
STUB per the assignment: `input_specs()` supplies precomputed frame
embeddings (B, F, d_model); the encoder is a bidirectional transformer over
them. Decoder layers add cross-attention whose K/V are computed once at
prefill (the "turn-1 compute-bound phase" for this family) and cached as
fixed entries."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (attn_skeleton, cross_attention, cross_attn_skeleton,
                        encode_cross_kv, gqa_decode, gqa_prefill,
                        online_attention)
from .config import ATTN_GLOBAL, ModelConfig
from .layers import (apply_mlp, apply_norm, embed, embed_skeleton,
                     mlp_skeleton, norm_skeleton, sds, sinusoidal_positions,
                     unembed, unembed_skeleton)
from .transformer import _stack_skeleton


def _scan_blocks(cfg, body, init, xs_tree):
    """lax.scan over stacked layer params, or a Python unroll in measurement
    mode (cfg.unroll_layers — XLA cost analysis counts loop bodies once; see
    benchmarks/roofline.py)."""
    if not cfg.unroll_layers:
        return jax.lax.scan(body, init, xs_tree)
    n = jax.tree_util.tree_leaves(xs_tree)[0].shape[0]
    carry, ys = init, []
    for i in range(n):
        xi = jax.tree_util.tree_map(lambda l: l[i], xs_tree)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def _enc_block_skeleton(cfg: ModelConfig):
    return {"ln1": norm_skeleton(cfg), "attn": attn_skeleton(cfg, ATTN_GLOBAL),
            "ln2": norm_skeleton(cfg), "mlp": mlp_skeleton(cfg)}


def _dec_block_skeleton(cfg: ModelConfig):
    return {"ln1": norm_skeleton(cfg), "attn": attn_skeleton(cfg, ATTN_GLOBAL),
            "lnx": norm_skeleton(cfg), "cross": cross_attn_skeleton(cfg),
            "ln2": norm_skeleton(cfg), "mlp": mlp_skeleton(cfg)}


def encdec_skeleton(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "embed": embed_skeleton(cfg),
        "encoder": _stack_skeleton(_enc_block_skeleton(cfg), cfg.n_encoder_layers),
        "enc_norm": norm_skeleton(cfg),
        "decoder": _stack_skeleton(_dec_block_skeleton(cfg), cfg.n_layers),
        "final_norm": norm_skeleton(cfg),
        "unembed": unembed_skeleton(cfg),
    }


def encdec_cache_skeleton(cfg: ModelConfig, batch: int, ctx: int):
    hd = cfg.head_dim
    L = cfg.n_layers
    return {
        "self": {"k": sds((L, batch, ctx, cfg.n_kv_heads, hd), cfg.dtype),
                 "v": sds((L, batch, ctx, cfg.n_kv_heads, hd), cfg.dtype)},
        "cross": {"k": sds((L, batch, cfg.encoder_seq, cfg.n_kv_heads, hd),
                           cfg.dtype),
                  "v": sds((L, batch, cfg.encoder_seq, cfg.n_kv_heads, hd),
                           cfg.dtype)},
    }


def run_encoder(params, cfg: ModelConfig, frame_embeds):
    """frame_embeds: (B, F, D) from the stub frontend."""
    B, F, D = frame_embeds.shape
    h = frame_embeds.astype(cfg.jnp_dtype) + sinusoidal_positions(
        F, D, cfg.jnp_dtype)[None]
    pos = jnp.arange(F)

    def block(hc, p):
        a = apply_norm(p["ln1"], cfg, hc)
        B_, F_, _ = a.shape
        hd = cfg.head_dim
        q = (a @ p["attn"]["wq"]).reshape(B_, F_, cfg.n_heads, hd)
        k = (a @ p["attn"]["wk"]).reshape(B_, F_, cfg.n_kv_heads, hd)
        v = (a @ p["attn"]["wv"]).reshape(B_, F_, cfg.n_kv_heads, hd)
        reps = cfg.n_heads // cfg.n_kv_heads
        if reps > 1:
            k, v = jnp.repeat(k, reps, 2), jnp.repeat(v, reps, 2)
        o = online_attention(q, k, v, pos, pos, causal=False)
        hc = hc + o.reshape(B_, F_, -1) @ p["attn"]["wo"]
        m = apply_norm(p["ln2"], cfg, hc)
        return hc + apply_mlp(p["mlp"], cfg, m), None

    h, _ = _scan_blocks(cfg, block, h, params["encoder"])
    return apply_norm(params["enc_norm"], cfg, h)


def encdec_prefill(params, cfg: ModelConfig, tokens, *, frontend_embeds,
                   caches: Optional[Dict] = None, start_pos: int = 0,
                   kv_lens=None):
    """Turn-1 prefill runs the encoder + computes per-layer cross K/V; later
    (append) prefills reuse the cached cross K/V (caches is not None)."""
    B, S = tokens.shape
    h = embed(params["embed"], cfg, tokens).astype(cfg.jnp_dtype)
    h = h + sinusoidal_positions(S + start_pos, cfg.d_model,
                                 cfg.jnp_dtype)[None, start_pos:]

    if caches is None:
        enc_out = run_encoder(params, cfg, frontend_embeds)

        def cross_kv(_, p):
            kv = encode_cross_kv(p["cross"], cfg, enc_out)
            return None, kv

        _, cross = _scan_blocks(cfg, cross_kv, None, params["decoder"])
    else:
        cross = caches["cross"]

    def block(hc, xs):
        p, xkv, prefix = xs
        a = apply_norm(p["ln1"], cfg, hc)
        out, newkv = gqa_prefill(p["attn"], cfg, ATTN_GLOBAL, a, start_pos,
                                 prefix_kv=prefix, kv_lens=kv_lens)
        hc = hc + out
        c = apply_norm(p["lnx"], cfg, hc)
        hc = hc + cross_attention(p["cross"], cfg, c, xkv)
        m = apply_norm(p["ln2"], cfg, hc)
        return hc + apply_mlp(p["mlp"], cfg, m), newkv

    prefix = None if caches is None else caches["self"]
    if prefix is None:
        h, self_kv = _scan_blocks(cfg, lambda hc, xs: block(hc, (*xs, None)),
                                  h, (params["decoder"], cross))
    else:
        h, self_kv = _scan_blocks(cfg, block, h,
                                  (params["decoder"], cross, prefix))
    h = apply_norm(params["final_norm"], cfg, h)
    logits = unembed(params.get("unembed", {}), params["embed"], cfg, h[:, -1])
    return logits, {"self": self_kv, "cross": cross}


def encdec_hidden(params, cfg: ModelConfig, tokens, *, frontend_embeds,
                  remat: bool = False, **_):
    """Training forward: full hidden states (B,S,D) post final norm."""
    B, S = tokens.shape
    h = embed(params["embed"], cfg, tokens).astype(cfg.jnp_dtype)
    h = h + sinusoidal_positions(S, cfg.d_model, cfg.jnp_dtype)[None]
    enc_out = run_encoder(params, cfg, frontend_embeds)

    def block(hc, p):
        a = apply_norm(p["ln1"], cfg, hc)
        out, _ = gqa_prefill(p["attn"], cfg, ATTN_GLOBAL, a, 0)
        hc = hc + out
        c = apply_norm(p["lnx"], cfg, hc)
        xkv = encode_cross_kv(p["cross"], cfg, enc_out)
        hc = hc + cross_attention(p["cross"], cfg, c, xkv)
        m = apply_norm(p["ln2"], cfg, hc)
        return hc + apply_mlp(p["mlp"], cfg, m), None

    body = jax.checkpoint(block) if remat else block
    h, _ = _scan_blocks(cfg, body, h, params["decoder"])
    return apply_norm(params["final_norm"], cfg, h), {}


def encdec_decode(params, cfg: ModelConfig, token, caches, position,
                  kv_lens=None):
    h = embed(params["embed"], cfg, token[:, None]).astype(cfg.jnp_dtype)
    # sinusoidal position for the current step (scalar or per-sequence)
    pos = jnp.asarray(position, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (token.shape[0],))
    pos_table = sinusoidal_positions(cfg.max_seq, cfg.d_model, cfg.jnp_dtype)
    h = h + pos_table[pos][:, None]

    def block(hc, xs):
        p, skv, xkv = xs
        a = apply_norm(p["ln1"], cfg, hc)
        out, newkv = gqa_decode(p["attn"], cfg, ATTN_GLOBAL, a, position, skv,
                                kv_lens=kv_lens)
        hc = hc + out
        c = apply_norm(p["lnx"], cfg, hc)
        hc = hc + cross_attention(p["cross"], cfg, c, xkv)
        m = apply_norm(p["ln2"], cfg, hc)
        return hc + apply_mlp(p["mlp"], cfg, m), newkv

    h, new_self = _scan_blocks(cfg, block, h, (params["decoder"],
                                               caches["self"],
                                               caches["cross"]))
    h = apply_norm(params["final_norm"], cfg, h)
    logits = unembed(params.get("unembed", {}), params["embed"], cfg, h[:, 0])
    return logits, {"self": new_self}
