from .config import ModelConfig, reduced_config
from .model import Model, build_model

__all__ = ["ModelConfig", "reduced_config", "Model", "build_model"]
