"""Recurrent sequence mixers: RWKV6 ("Finch", data-dependent decay) and
RG-LRU (RecurrentGemma / Griffin real-gated linear recurrent unit).

Both expose a chunk-parallel prefill (compile-friendly: scan over chunks, not
tokens; all decay exponents are differences along time so every exp() argument
is <= 0 — numerically safe) and an O(1)-state decode step. These are the
model-side reference implementations; `repro/kernels` holds the Pallas TPU
versions validated against `kernels/ref.py`.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import sds

# --------------------------------------------------------------------------- #
# RWKV6 time-mix
# --------------------------------------------------------------------------- #
def _rwkv_dims(cfg: ModelConfig):
    """(n_heads_padded, attention width). rwkv_pad_heads_to pads the head
    axis so it TP-shards without resharding collectives (§Perf)."""
    hs = cfg.rwkv_head_size
    nh = cfg.d_model // hs
    nh_pad = max(cfg.rwkv_pad_heads_to, nh) if cfg.rwkv_pad_heads_to else nh
    return nh, nh_pad, nh_pad * hs


def rwkv6_skeleton(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    nh, nh_pad, da = _rwkv_dims(cfg)
    lora = max(32, d // 32)
    return {
        # token-shift lerp coefficients per projection
        "mu_r": sds((d,), cfg.dtype), "mu_k": sds((d,), cfg.dtype),
        "mu_v": sds((d,), cfg.dtype), "mu_g": sds((d,), cfg.dtype),
        "mu_w": sds((d,), cfg.dtype),
        "wr": sds((d, da), cfg.dtype), "wk": sds((d, da), cfg.dtype),
        "wv": sds((d, da), cfg.dtype), "wg": sds((d, da), cfg.dtype),
        "wo": sds((da, d), cfg.dtype),
        # data-dependent decay LoRA: w = exp(-exp(w0 + tanh(x A) B))
        "w0": sds((da,), "float32"),
        "wA": sds((d, lora), cfg.dtype), "wB": sds((lora, da), cfg.dtype),
        "bonus_u": sds((nh_pad, hs), "float32"),
        "ln_y": sds((da,), cfg.dtype),  # group-norm scale on wkv output
    }


def _rwkv_mix(params, x, x_prev):
    """Token shift: per-projection lerp between x_t and x_{t-1}.
    x: (B,S,D); x_prev: (B,1,D) last token of previous segment."""
    shifted = jnp.concatenate([x_prev, x[:, :-1]], axis=1)

    def lerp(mu):
        return x + (shifted - x) * jax.nn.sigmoid(mu.astype(jnp.float32)).astype(x.dtype)

    return {k: lerp(params[f"mu_{k}"]) for k in ("r", "k", "v", "g", "w")}


def _rwkv_rkvwg(params, cfg, x, x_prev):
    B, S, D = x.shape
    hs = cfg.rwkv_head_size
    nh, nh_pad, _ = _rwkv_dims(cfg)
    m = _rwkv_mix(params, x, x_prev)
    r = (m["r"] @ params["wr"]).reshape(B, S, nh_pad, hs)
    k = (m["k"] @ params["wk"]).reshape(B, S, nh_pad, hs)
    v = (m["v"] @ params["wv"]).reshape(B, S, nh_pad, hs)
    g = jax.nn.silu(m["g"] @ params["wg"])
    logw = -jnp.exp(
        params["w0"].astype(jnp.float32)
        + (jnp.tanh(m["w"] @ params["wA"]) @ params["wB"]).astype(jnp.float32)
    ).reshape(B, S, nh_pad, hs)  # log decay, strictly < 0
    if nh_pad != nh:
        # dead padded heads: zero r so they contribute nothing downstream
        mask = (jnp.arange(nh_pad) < nh).astype(r.dtype)[None, None, :, None]
        r = r * mask
    return r, k, v, g, logw


def wkv6_chunked(r, k, v, logw, u, state, chunk: int = 64):
    """Chunk-parallel WKV6. r,k,v: (B,S,H,hs) fp-any; logw: (B,S,H,hs) fp32
    (< 0); u: (H,hs); state: (B,H,hs,hs) fp32 (key-major, value-minor).
    Returns (y (B,S,H,hs), final_state)."""
    B, S, H, hs = r.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))  # pad decay=e^0? no:
        # padded steps must not disturb state: set their k=0 (z did) and decay=1
        # (logw=0) so S_t carries through; y on pads is discarded.
    n = (S + pad) // c
    rs = r.astype(jnp.float32).reshape(B, n, c, H, hs)
    ks = k.astype(jnp.float32).reshape(B, n, c, H, hs)
    vs = v.astype(jnp.float32).reshape(B, n, c, H, hs)
    ws = logw.reshape(B, n, c, H, hs)

    tri_lower = jnp.tril(jnp.ones((c, c), bool), k=-1)

    def chunk_step(S0, inp):
        rc, kc, vc, wc = inp  # (B,c,H,hs)
        cum = jnp.cumsum(wc, axis=1)  # inclusive cumulative log-decay
        # intra-chunk: A[t,j] = sum_i r[t,i] k[j,i] exp(cum[t-1,i]-cum[j,i]), j<t
        # exponent = (cum[t] - w[t]) - cum[j] <= 0 for j <= t-1
        e_t = cum - wc  # cum_{t-1}
        dmat = e_t[:, :, None] - cum[:, None, :]  # (B,t,j,H,hs)
        A = jnp.einsum("bthi,bjhi,btjhi->bhtj", rc, kc,
                       jnp.exp(jnp.minimum(dmat, 0.0)) * tri_lower[None, :, :, None, None])
        # diagonal bonus term
        diag = jnp.einsum("bthi,bthi->bht", rc, u[None, None] * kc)
        A = A + jnp.eye(c)[None, None] * diag[..., None]
        y = jnp.einsum("bhtj,bjhi->bthi", A, vc)
        # cross-chunk: y_t += (r_t * exp(cum_{t-1})) . S0
        r_dec = rc * jnp.exp(e_t)
        y = y + jnp.einsum("bthi,bhij->bthj", r_dec, S0)
        # state update: S1 = diag(exp(cum_c)) S0 + sum_j exp(cum_c - cum_j) k_j v_j^T
        tot = cum[:, -1]  # (B,H,hs)
        k_dec = kc * jnp.exp(tot[:, None] - cum)
        S1 = jnp.exp(tot)[..., None] * S0 + jnp.einsum("bjhi,bjhv->bhiv", k_dec, vc)
        return S1, y

    final, ys = jax.lax.scan(
        chunk_step, state.astype(jnp.float32),
        (rs.transpose(1, 0, 2, 3, 4), ks.transpose(1, 0, 2, 3, 4),
         vs.transpose(1, 0, 2, 3, 4), ws.transpose(1, 0, 2, 3, 4)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S + pad, H, hs)[:, :S]
    return y, final


def _groupnorm_heads(y, scale, eps=1e-5):
    """Per-head layernorm on (B,S,H,hs), then flatten and scale."""
    B, S, H, hs = y.shape
    mu = y.mean(-1, keepdims=True)
    var = ((y - mu) ** 2).mean(-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + eps)
    return y.reshape(B, S, H * hs) * scale.astype(y.dtype)


def rwkv6_prefill(params, cfg: ModelConfig, x, state: Dict):
    """state: {"s": (B,H,hs,hs) f32, "shift": (B,1,D)}. Returns (out, state')."""
    r, k, v, g, logw = _rwkv_rkvwg(params, cfg, x, state["shift"])
    y, s1 = wkv6_chunked(r, k, v, logw, params["bonus_u"], state["s"])
    out = _groupnorm_heads(y, params["ln_y"]).astype(x.dtype) * g
    return out @ params["wo"], {"s": s1, "shift": x[:, -1:]}


def rwkv6_decode(params, cfg: ModelConfig, x1, state: Dict):
    """Single-token step. y = r.(S + (u*k) v^T); S' = e^{logw} (.) S + k v^T."""
    r, k, v, g, logw = _rwkv_rkvwg(params, cfg, x1, state["shift"])
    rf, kf, vf = (a[:, 0].astype(jnp.float32) for a in (r, k, v))
    S0 = state["s"]
    u = params["bonus_u"][None]
    y = jnp.einsum("bhi,bhij->bhj", rf, S0) + (
        jnp.einsum("bhi,bhi->bh", rf, u * kf)[..., None] * vf)
    S1 = jnp.exp(logw[:, 0])[..., None] * S0 + jnp.einsum("bhi,bhv->bhiv", kf, vf)
    y = y[:, None].reshape(*x1.shape[:2], -1, cfg.rwkv_head_size)
    out = _groupnorm_heads(y, params["ln_y"]).astype(x1.dtype) * g
    return out @ params["wo"], {"s": S1, "shift": x1}


def rwkv6_init_state(cfg: ModelConfig, batch: int):
    hs = cfg.rwkv_head_size
    _, nh_pad, _ = _rwkv_dims(cfg)
    return {"s": jnp.zeros((batch, nh_pad, hs, hs), jnp.float32),
            "shift": jnp.zeros((batch, 1, cfg.d_model), cfg.jnp_dtype)}


# RWKV channel-mix (the family's MLP replacement)
def rwkv_cmix_skeleton(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    return {"mu_k": sds((d,), cfg.dtype), "mu_r": sds((d,), cfg.dtype),
            "wk": sds((d, cfg.d_ff), cfg.dtype),
            "wv": sds((cfg.d_ff, d), cfg.dtype),
            "wr": sds((d, d), cfg.dtype)}


def rwkv_cmix(params, cfg: ModelConfig, x, x_prev):
    shifted = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    lerp = lambda mu: x + (shifted - x) * jax.nn.sigmoid(
        mu.astype(jnp.float32)).astype(x.dtype)
    kx, rx = lerp(params["mu_k"]), lerp(params["mu_r"])
    k = jnp.square(jax.nn.relu(kx @ params["wk"]))
    return jax.nn.sigmoid(rx @ params["wr"]) * (k @ params["wv"]), x[:, -1:]


# --------------------------------------------------------------------------- #
# RG-LRU (RecurrentGemma / Griffin)
# --------------------------------------------------------------------------- #
RGLRU_C = 8.0


def rglru_skeleton(cfg: ModelConfig) -> Dict[str, Any]:
    d, w = cfg.d_model, cfg.lru_width
    return {
        "w_in": sds((d, w), cfg.dtype),   # recurrent branch input proj
        "w_gate": sds((d, w), cfg.dtype),  # gelu gate branch
        "w_out": sds((w, d), cfg.dtype),
        "conv_k": sds((cfg.conv1d_width, w), cfg.dtype),
        "conv_b": sds((w,), cfg.dtype),
        "w_a": sds((w, w), cfg.dtype), "b_a": sds((w,), "float32"),
        "w_i": sds((w, w), cfg.dtype), "b_i": sds((w,), "float32"),
        "lam": sds((w,), "float32"),  # Λ — per-channel base decay
    }


def _causal_conv1d(u, kern, bias, prev):
    """u: (B,S,W); kern: (K,W); prev: (B,K-1,W) carried inputs."""
    K = kern.shape[0]
    full = jnp.concatenate([prev, u], axis=1)
    out = sum(full[:, i : i + u.shape[1]] * kern[K - 1 - i]
              for i in range(K))
    return out + bias, full[:, -(K - 1):]


def _rglru_gates(params, u):
    a_gate = jax.nn.sigmoid(u.astype(jnp.float32) @ params["w_a"].astype(jnp.float32)
                            + params["b_a"])
    i_gate = jax.nn.sigmoid(u.astype(jnp.float32) @ params["w_i"].astype(jnp.float32)
                            + params["b_i"])
    log_a = -RGLRU_C * jax.nn.softplus(params["lam"]) * a_gate  # <= 0
    return log_a, i_gate


def rglru_prefill(params, cfg: ModelConfig, x, state: Dict):
    """state: {"h": (B,W) f32, "conv": (B,K-1,W)}. Associative-scan prefill."""
    u = x @ params["w_in"]
    u, conv1 = _causal_conv1d(u, params["conv_k"], params["conv_b"],
                              state["conv"].astype(x.dtype))
    log_a, i_gate = _rglru_gates(params, u)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i_gate * u.astype(jnp.float32))
    # fold carried state into the first step: h_1 = a_1 h_0 + b_1
    b = b.at[:, 0].add(a[:, 0] * state["h"])

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_sc, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    del a_sc
    gate = jax.nn.gelu(x @ params["w_gate"])
    out = (h.astype(x.dtype) * gate) @ params["w_out"]
    return out, {"h": h[:, -1], "conv": conv1}


def rglru_decode(params, cfg: ModelConfig, x1, state: Dict):
    u = x1 @ params["w_in"]
    u, conv1 = _causal_conv1d(u, params["conv_k"], params["conv_b"],
                              state["conv"].astype(x1.dtype))
    log_a, i_gate = _rglru_gates(params, u[:, 0:1])
    a = jnp.exp(log_a[:, 0])
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i_gate[:, 0] * u[:, 0].astype(jnp.float32))
    h = a * state["h"] + b
    gate = jax.nn.gelu(x1 @ params["w_gate"])
    out = (h[:, None].astype(x1.dtype) * gate) @ params["w_out"]
    return out, {"h": h, "conv": conv1}


def rglru_init_state(cfg: ModelConfig, batch: int):
    return {"h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv1d_width - 1, cfg.lru_width),
                              cfg.jnp_dtype)}
