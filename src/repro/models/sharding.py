"""PartitionSpec assignment for every param / cache / activation tree.

Strategy (DESIGN.md §5):
  * Weights: Megatron-style TP on the `model` axis — Q heads, d_ff, vocab and
    experts are the sharded dimensions; GQA K/V projections stay replicated
    (small; avoids padded-head reshapes under TP > n_kv_heads).
  * Batch/token dims: sharded over (`pod`,`data`) — `dp_axes`.
  * Decode KV caches: batch over data axes, KV *length* over `model`
    (context-parallel flash-decode).
  * Everything is assigned by tree-path pattern so new param leaves
    automatically inherit sensible specs.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .config import ModelConfig

TP = "model"


def mesh_axes(mesh: Mesh) -> Tuple[Tuple[str, ...], str]:
    """Returns (dp_axes, tp_axis) from a mesh's axis names."""
    names = mesh.axis_names
    dp = tuple(n for n in names if n != TP)
    return dp, TP


# name -> function(shape_rank_without_group_dim) -> PartitionSpec tail
_LAST_DIM_TP = {"wq", "wi", "wg", "w_uq", "w_in", "w_gate", "wr"}
_FIRST_DIM_TP = {"wo", "w_out"}
_REPLICATED = {"wk", "wv", "w_dq", "w_dkv", "wA", "wB", "router", "conv_k",
               "conv_b", "w_a", "w_i", "b_a", "b_i", "lam", "w0", "bonus_u",
               "scale", "q_scale", "k_scale", "ln_y", "bias",
               "mu_r", "mu_k", "mu_v", "mu_g", "mu_w"}
# (E, D, Fe)/(E, Fe, D) expert tensors: expert dim sharded (EP)
_EXPERT_TP = {"wi", "wg", "wo"}


def _leaf_spec(path, leaf, cfg: ModelConfig) -> P:
    names = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
    name = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    grouped = names[0] in ("groups", "encoder", "decoder") or (
        len(names) >= 2 and names[0] == "groups")
    rank = len(leaf.shape)
    lead = (None,) if grouped else ()

    def spec(*tail):
        full = (*lead, *tail)
        # pad with None up to rank
        full = full + (None,) * (rank - len(full))
        return P(*full[:rank])

    if parent == "moe" and name in _EXPERT_TP:
        return spec(TP, None, None)  # (E, D, F) — expert-parallel
    if parent == "embed" and name == "w":
        return P(TP, None)  # vocab-sharded (never grouped)
    if parent == "unembed" and name == "w":
        return P(None, TP)
    if name in ("w_uk", "w_uv"):  # (rank, H, hd): shard heads
        return spec(None, TP, None)
    if name in _REPLICATED or parent in ("ln1", "ln2", "lnx", "final_norm",
                                         "enc_norm", "norm"):
        return spec()
    if name in _LAST_DIM_TP:
        return spec(*([None] * (rank - len(lead) - 1)), TP)
    if name in _FIRST_DIM_TP:
        return spec(TP)
    if parent == "cmix" and name in ("wk",):
        return spec(None, TP)
    if parent == "cmix" and name in ("wv",):
        return spec(TP, None)
    return spec()


def param_pspecs(cfg: ModelConfig, skeleton, mode: str = "tp") -> Any:
    """PartitionSpec tree matching a param skeleton.

    mode="tp":   Megatron tensor parallelism on the model axis (baseline —
                 the serving-style layout the paper's replicas use).
    mode="fsdp": ZeRO-3: every weight shards its largest model-axis-divisible
                 dim; GSPMD all-gathers weights at use and reduce-scatters
                 grads. For train_4k (B_loc·S·D >> per-layer params) this
                 moves ~4x fewer collective bytes than TP (§Perf iteration).
    """
    if mode == "tp":
        return jax.tree_util.tree_map_with_path(
            lambda p, l: _leaf_spec(p, l, cfg), skeleton)

    def fsdp_spec(path, leaf):
        shape = leaf.shape
        # pick the largest dim divisible by 16 (mesh model-axis size)
        best, best_dim = -1, None
        for i, d in enumerate(shape):
            if d % 16 == 0 and d > best:
                best, best_dim = d, i
        if best_dim is None:
            return P()
        spec = [None] * len(shape)
        spec[best_dim] = TP
        return P(*spec)

    return jax.tree_util.tree_map_with_path(fsdp_spec, skeleton)


def cache_pspecs(cfg: ModelConfig, cache_skeleton, dp_axes) -> Any:
    """Decode caches: batch -> dp, length -> TP for growing entries; recurrent
    states: batch -> dp only."""
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def one(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
        name = names[-1]
        grouped = names[0] in ("groups",) or (
            names[0] in ("self", "cross") and len(leaf.shape) == 5)
        lead = (None,) if grouped else ()
        rank = len(leaf.shape)
        if name in ("k", "v", "ckv", "krope"):
            # cross-attention caches have a fixed short length (encoder_seq,
            # not a multiple of TP) — shard batch only
            ln = None if "cross" in names else TP
            tail = (dp, ln) + (None,) * (rank - len(lead) - 2)
            return P(*lead, *tail)
        # recurrent state: batch only
        tail = (dp,) + (None,) * (rank - len(lead) - 1)
        return P(*lead, *tail)

    return jax.tree_util.tree_map_with_path(one, cache_skeleton)


def data_pspec(dp_axes, rank: int) -> P:
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    return P(dp, *([None] * (rank - 1)))


def with_named_sharding(mesh: Mesh, tree, pspecs):
    return jax.tree_util.tree_map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                          sharding=NamedSharding(mesh, s)),
        tree, pspecs)
