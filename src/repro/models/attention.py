"""Attention: blocked online-softmax prefill (global / sliding-window), GQA
decode against a (possibly length-sharded) KV cache, and MLA (DeepSeek-style
latent attention) with the absorbed-matrix decode path.

Conventions
-----------
* Prefill/train attention expands GQA KV heads to full `n_heads` before the
  einsums (KV projections are small and kept replicated under TP; Q heads are
  the TP-sharded dimension).
* Decode attention keeps Q replicated and shards the *KV length* dimension —
  context-parallel flash-decode, matching the memory-bound tail of the paper.
* Decode never updates the big cache in-program: it returns the new token's
  KV entries; the engine/cache-manager owns the append (see DESIGN.md §5).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ATTN_GLOBAL, ATTN_LOCAL, ATTN_MLA, ModelConfig
from .layers import apply_rope, sds

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# Param skeletons
# --------------------------------------------------------------------------- #
def attn_skeleton(cfg: ModelConfig, kind: str, cross: bool = False) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.head_dim
    if kind == ATTN_MLA:
        qd = cfg.qk_nope_dim + cfg.qk_rope_dim
        sk = {
            "w_dkv": sds((d, cfg.kv_lora_rank + cfg.qk_rope_dim), cfg.dtype),
            "w_uk": sds((cfg.kv_lora_rank, cfg.n_heads, cfg.qk_nope_dim), cfg.dtype),
            "w_uv": sds((cfg.kv_lora_rank, cfg.n_heads, cfg.v_head_dim), cfg.dtype),
            "wo": sds((cfg.n_heads * cfg.v_head_dim, d), cfg.dtype),
        }
        if cfg.q_lora_rank:
            sk["w_dq"] = sds((d, cfg.q_lora_rank), cfg.dtype)
            sk["w_uq"] = sds((cfg.q_lora_rank, cfg.n_heads * qd), cfg.dtype)
        else:
            sk["wq"] = sds((d, cfg.n_heads * qd), cfg.dtype)
        return sk
    sk = {
        "wq": sds((d, cfg.n_heads * hd), cfg.dtype),
        "wk": sds((d, cfg.n_kv_heads * hd), cfg.dtype),
        "wv": sds((d, cfg.n_kv_heads * hd), cfg.dtype),
        "wo": sds((cfg.n_heads * hd, d), cfg.dtype),
    }
    if cfg.qk_norm and not cross:
        sk["q_scale"] = sds((hd,), cfg.dtype)
        sk["k_scale"] = sds((hd,), cfg.dtype)
    return sk


def rope_single(x, positions, theta: float):
    """RoPE for a single decode step with PER-SEQUENCE positions.
    x: (B, 1, H, D) or (B, 1, D); positions: (B,) or scalar int32."""
    from .layers import rope_freqs
    pos = jnp.asarray(positions, jnp.float32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (x.shape[0],))
    dim = x.shape[-1]
    inv = rope_freqs(dim, theta)
    ang = pos[:, None] * inv  # (B, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    shape = (x.shape[0],) + (1,) * (x.ndim - 2) + (dim // 2,)
    cos, sin = cos.reshape(shape), sin.reshape(shape)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _qk_norm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (xf * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def _repeat_kv(k, n_heads):
    """(B, T, Hkv, D) -> (B, T, H, D)."""
    reps = n_heads // k.shape[2]
    if reps == 1:
        return k
    return jnp.repeat(k, reps, axis=2)


# --------------------------------------------------------------------------- #
# Blocked online-softmax attention (the jnp flash oracle)
# --------------------------------------------------------------------------- #
def online_attention(
    q, k, v, q_pos, kv_pos, *, causal: bool = True, window: int = 0,
    q_chunk: int = 256, kv_chunk: int = 512, kv_lens=None, kv_valid=None,
):
    """q: (B,Sq,H,D); k,v: (B,Skv,H,D); q_pos: (Sq,), kv_pos: (Skv,) int32.

    Scans over Q chunks, inner-scans over KV chunks with online softmax —
    structurally the flash algorithm, bounding temporaries to
    (B, H, q_chunk, kv_chunk). `kv_lens` (B,) optionally masks per-batch
    ragged valid lengths; `kv_valid` (B, Skv) bool is the general per-entry
    validity mask (engine slot buffers)."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)

    pq = (-Sq) % q_chunk
    pk = (-Skv) % kv_chunk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pq), constant_values=-1)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pk), constant_values=jnp.iinfo(jnp.int32).max)
        if kv_valid is not None:
            kv_valid = jnp.pad(kv_valid, ((0, 0), (0, pk)))

    nq, nk = (Sq + pq) // q_chunk, (Skv + pk) // kv_chunk
    qc = q.reshape(B, nq, q_chunk, H, D)
    kc = k.reshape(B, nk, kv_chunk, H, D)
    vc = v.reshape(B, nk, kv_chunk, H, D)
    qpc = q_pos.reshape(nq, q_chunk)
    kpc = kv_pos.reshape(nk, kv_chunk)
    kvc = (kv_valid.reshape(B, nk, kv_chunk).transpose(1, 0, 2)
           if kv_valid is not None else None)

    def q_step(_, qi):
        q_blk, qp = qi  # (B,Cq,H,D), (Cq,)

        def kv_step(carry, ki):
            m, l, acc = carry
            if kvc is not None:
                k_blk, v_blk, kp, kval = ki
            else:
                k_blk, v_blk, kp = ki
                kval = None
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            ok = (kp[None, :] >= 0) & (qp[:, None] >= 0)
            if causal:
                ok &= kp[None, :] <= qp[:, None]
            if window:
                ok &= kp[None, :] > qp[:, None] - window
            mask = ok[None, None]
            if kv_lens is not None:
                mask = mask & (kp[None, None, None, :]
                               < kv_lens[:, None, None, None])
            if kval is not None:
                mask = mask & kval[:, None, None, :]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, D), jnp.float32)
        xs = (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), kpc)
        if kvc is not None:
            xs = (*xs, kvc)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), xs)
        out = acc / jnp.maximum(l[..., None], 1e-20)
        return None, out.transpose(0, 2, 1, 3)  # (B,Cq,H,D)

    _, outs = jax.lax.scan(q_step, None,
                           (qc.transpose(1, 0, 2, 3, 4), qpc))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq + pq, H, D)
    return out[:, :Sq].astype(q.dtype)


# --------------------------------------------------------------------------- #
# Custom-VJP flash attention (training memory; §Perf iteration 1)
#
# jax.lax.scan's backward saves every step's online-softmax carriers
# (m, l, acc) — O(S·D) per KV chunk per layer, the dominant train-time
# temporary. The custom VJP saves only (out, lse) and RECOMPUTES attention
# probabilities chunk-by-chunk in the backward pass — the flash-attention
# backward, in pure jnp.
# --------------------------------------------------------------------------- #
def _flash_fwd_impl(q, k, v, q_start, kv_start, causal, window, kv_chunk):
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    kv_chunk = min(kv_chunk, Skv)
    pk = (-Skv) % kv_chunk
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nk = (Skv + pk) // kv_chunk
    kc = k.reshape(B, nk, kv_chunk, H, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, kv_chunk, H, D).transpose(1, 0, 2, 3, 4)
    qpos = q_start + jnp.arange(Sq)

    def step(carry, ji):
        m, l, acc = carry
        k_blk, v_blk, j = ji
        kpos = kv_start + j * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k_blk,
                       preferred_element_type=jnp.float32) * scale
        ok = kpos[None, :] < Skv + kv_start
        ok &= (kpos[None, :] <= qpos[:, None]) if causal else ok
        if window:
            ok = ok & (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(ok[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, v_blk.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kc, vc, jnp.arange(nk)))
    out = (acc / jnp.maximum(l[..., None], 1e-20)).transpose(0, 2, 1, 3)
    lse = m + jnp.log(jnp.maximum(l, 1e-20))  # (B, H, Sq)
    return out.astype(q.dtype), lse


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, q_start, kv_start, causal, window, kv_chunk):
    """q: (B,Sq,H,D); k,v: (B,Skv,H,D) (heads pre-expanded). Causal /
    sliding-window attention with O(1)-in-S saved residuals."""
    return _flash_fwd_impl(q, k, v, q_start, kv_start, causal, window,
                           kv_chunk)[0]


def _flash_fwd(q, k, v, q_start, kv_start, causal, window, kv_chunk):
    out, lse = _flash_fwd_impl(q, k, v, q_start, kv_start, causal, window,
                               kv_chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd(q_start, kv_start, causal, window, kv_chunk, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    kv_chunk = min(kv_chunk, Skv)
    pk = (-Skv) % kv_chunk
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nk = (Skv + pk) // kv_chunk
    kc = k.reshape(B, nk, kv_chunk, H, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, kv_chunk, H, D).transpose(1, 0, 2, 3, 4)
    qpos = q_start + jnp.arange(Sq)
    do = dout.astype(jnp.float32)
    # Delta_i = rowsum(dout * out)
    Dl = jnp.einsum("bqhd,bqhd->bhq", do, out.astype(jnp.float32))

    def step(dq, ji):
        k_blk, v_blk, j = ji
        kpos = kv_start + j * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k_blk,
                       preferred_element_type=jnp.float32) * scale
        ok = kpos[None, :] < Skv + kv_start
        ok &= (kpos[None, :] <= qpos[:, None]) if causal else ok
        if window:
            ok = ok & (kpos[None, :] > qpos[:, None] - window)
        p = jnp.where(ok[None, None],
                      jnp.exp(s - lse[..., None]), 0.0)  # recomputed probs
        dv = jnp.einsum("bhqk,bqhd->bkhd", p, do)
        dp = jnp.einsum("bqhd,bkhd->bhqk", do, v_blk.astype(jnp.float32))
        ds = p * (dp - Dl[..., None]) * scale
        dq = dq + jnp.einsum("bhqk,bkhd->bqhd", ds, k_blk.astype(jnp.float32))
        dk = jnp.einsum("bhqk,bqhd->bkhd", ds, q.astype(jnp.float32))
        return dq, (dk, dv)

    dq0 = jnp.zeros((B, Sq, H, D), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(step, dq0, (kc, vc, jnp.arange(nk)))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, Skv + pk, H, D)[:, :Skv]
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, Skv + pk, H, D)[:, :Skv]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


# --------------------------------------------------------------------------- #
# KV-cache quantization (decode tail; §Perf iteration 3)
# --------------------------------------------------------------------------- #
def quantize_kv(x, cfg: ModelConfig):
    if not cfg.kv_cache_dtype or cfg.kv_cache_dtype == cfg.dtype:
        return x
    s = cfg.kv_quant_scale
    return jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127).astype(
        jnp.dtype(cfg.kv_cache_dtype))


def dequantize_kv(x, cfg: ModelConfig):
    if not cfg.kv_cache_dtype or x.dtype == cfg.jnp_dtype:
        return x
    return (x.astype(jnp.float32) * cfg.kv_quant_scale).astype(cfg.jnp_dtype)


def local_attention(q, k, v, q_start: int, window: int, *,
                    q_chunk: int = 256):
    """Sliding-window causal attention, linear in sequence length.

    q, k, v: (B, S, H, D) aligned (kv covers the same positions as q plus any
    cached prefix to the left already included in k/v). Each Q chunk slices
    exactly `window + q_chunk` keys via dynamic_slice — O(S·W) total."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    prefix = Skv - Sq  # cached tokens to the left of q
    scale = 1.0 / math.sqrt(D)
    q_chunk = min(q_chunk, Sq)
    pq = (-Sq) % q_chunk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    nq = (Sq + pq) // q_chunk
    span = window + q_chunk  # keys visible to one q chunk
    # left-pad kv so every slice is in-bounds
    k_pad = jnp.pad(k, ((0, 0), (span, 0), (0, 0), (0, 0)))
    v_pad = jnp.pad(v, ((0, 0), (span, 0), (0, 0), (0, 0)))

    def q_step(_, i):
        q_blk = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, axis=1)
        # keys ending exactly at this chunk's end (global index prefix+i*Cq+Cq)
        start = prefix + i * q_chunk + q_chunk + span - span  # = prefix+i*Cq+Cq
        k_blk = jax.lax.dynamic_slice_in_dim(k_pad, start, span, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(v_pad, start, span, axis=1)
        qp = q_start + i * q_chunk + jnp.arange(q_chunk)
        kp = q_start + i * q_chunk + q_chunk - span + jnp.arange(span)
        s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk,
                       preferred_element_type=jnp.float32) * scale
        ok = (kp[None, :] <= qp[:, None]) & (kp[None, :] > qp[:, None] - window)
        ok &= kp[None, :] >= 0
        s = jnp.where(ok[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", p, v_blk.astype(jnp.float32))
        return None, out

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq + pq, H, D)
    return out[:, :Sq].astype(q.dtype)


# --------------------------------------------------------------------------- #
# Decode attention (context-parallel flash-decode)
# --------------------------------------------------------------------------- #
def _partial_softmax(s, mask):
    s = jnp.where(mask, s, NEG_INF)
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    return m, p.sum(-1), p


def decode_attention(q1, k_cache, v_cache, k_new, v_new, *,
                     kv_lens=None, window: int = 0, pos=None):
    """One-token GQA attention against cache + the freshly produced token.

    q1: (B, 1, H, D); caches: (B, L, Hkv, D); new: (B, 1, Hkv, D).
    Uses a two-branch flash combine so the (possibly length-sharded) cache is
    read-only and never concatenated with the new token."""
    B, _, H, D = q1.shape
    L = k_cache.shape[1]
    Hkv = k_cache.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = q1.reshape(B, Hkv, G, D)

    s_c = jnp.einsum("bngd,blnd->bngl", qg, k_cache,
                     preferred_element_type=jnp.float32) * scale
    idx = jnp.arange(L)
    mask_c = jnp.ones((B, 1, 1, L), bool)
    if kv_lens is not None:
        mask_c &= idx[None, None, None, :] < kv_lens[:, None, None, None]
    if window and pos is not None:
        p_ = jnp.asarray(pos)
        p_ = p_.reshape(-1, 1, 1, 1) if p_.ndim else p_
        mask_c &= idx[None, None, None, :] > (p_ - window)
    m_c, l_c, p_c = _partial_softmax(s_c, mask_c)
    o_c = jnp.einsum("bngl,blnd->bngd", p_c, v_cache.astype(jnp.float32))

    s_n = jnp.einsum("bngd,blnd->bngl", qg, k_new,
                     preferred_element_type=jnp.float32) * scale
    m_n, l_n, p_n = _partial_softmax(s_n, jnp.ones_like(s_n, bool))
    o_n = jnp.einsum("bngl,blnd->bngd", p_n, v_new.astype(jnp.float32))

    m = jnp.maximum(m_c, m_n)
    c_c, c_n = jnp.exp(m_c - m), jnp.exp(m_n - m)
    l = l_c * c_c + l_n * c_n
    out = (o_c * c_c[..., None] + o_n * c_n[..., None]) / jnp.maximum(
        l[..., None], 1e-20)
    return out.reshape(B, 1, H, D).astype(q1.dtype)


# --------------------------------------------------------------------------- #
# Full attention blocks (projection + rope + attention + output)
# --------------------------------------------------------------------------- #
def _proj_qkv(params, cfg: ModelConfig, x):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = (x @ params["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (x @ params["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (x @ params["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    if "q_scale" in params:
        q = _qk_norm(q, params["q_scale"])
        k = _qk_norm(k, params["k_scale"])
    return q, k, v


def gqa_prefill(params, cfg: ModelConfig, kind: str, x, start_pos: int,
                prefix_kv: Optional[Dict] = None, kv_lens=None,
                prefix_start: Optional[int] = None,
                attention_impl: str = "xla"):
    """Prefill / append-prefill. Returns (out, {"k","v"} new-token cache).

    prefix_kv layouts:
      * default (prefix_start=None): the prefix buffer ends exactly at
        start_pos (contiguous history, dry-run / exact append).
      * engine slots (prefix_start=0): the prefix buffer starts at position
        0 and may be right-padded beyond the live length; pass kv_lens to
        mask the padding.

    `attention_impl="pallas"` (static) routes FRESH global-attention
    prefill (no prefix, no kv_lens masking, no window) through the
    flash-prefill kernel — native on TPU, interpret-mode elsewhere. The
    kernel computes plain causal attention over the padded bucket, which
    is exactly what the engine's turn-1 prefill needs (padded positions
    attend only rightward of the live tokens; their outputs and KV are
    discarded/masked by the caller). Append-prefill prefix reads, ragged
    kv_lens masks and sliding windows fall back to the jnp paths below.
    """
    B, S, _ = x.shape
    q, k, v = _proj_qkv(params, cfg, x)
    theta = cfg.rope_theta if kind == ATTN_GLOBAL else getattr(
        cfg, "rope_theta_local", cfg.rope_theta)
    pos = start_pos + jnp.arange(S)
    q = apply_rope(q, pos, theta)
    k = apply_rope(k, pos, theta)
    new_cache = {"k": k, "v": v}

    window = cfg.window if kind == ATTN_LOCAL else 0
    if prefix_kv is not None:
        P = prefix_kv["k"].shape[1]
        pstart = (start_pos - P) if prefix_start is None else prefix_start
        kv_pos = jnp.concatenate([pstart + jnp.arange(P), pos])
        k_all = jnp.concatenate(
            [_repeat_kv(prefix_kv["k"], cfg.n_heads),
             _repeat_kv(k, cfg.n_heads)], axis=1)
        v_all = jnp.concatenate(
            [_repeat_kv(prefix_kv["v"], cfg.n_heads),
             _repeat_kv(v, cfg.n_heads)], axis=1)
        kv_valid = None
        if kv_lens is not None:
            # padding lives only in the prefix region; new tokens are valid
            kv_valid = jnp.concatenate(
                [jnp.arange(P)[None, :] < kv_lens[:, None],
                 jnp.ones((x.shape[0], S), bool)], axis=1)
        out = online_attention(q, k_all, v_all, pos, kv_pos, causal=True,
                               window=window, kv_valid=kv_valid)
    else:
        kf = _repeat_kv(k, cfg.n_heads)
        vf = _repeat_kv(v, cfg.n_heads)
        use_pallas = (attention_impl == "pallas" and kv_lens is None
                      and window == 0
                      and (S <= 128 or S % 128 == 0))
        if use_pallas:
            from repro.kernels import ops
            out = ops.prefill_attention(q, kf, vf, window=0, impl="pallas")
        elif cfg.flash_vjp and kv_lens is None and not cfg.attn_block_full:
            out = flash_attention(q, kf, vf, start_pos, start_pos, True,
                                  window, 512)
        elif kind == ATTN_LOCAL and cfg.window and not cfg.attn_block_full:
            out = local_attention(q, kf, vf, start_pos, cfg.window)
        else:
            kv_pos = start_pos + jnp.arange(S)
            ch = (1 << 30) if cfg.attn_block_full else 256
            out = online_attention(q, kf, vf, pos, kv_pos, causal=True,
                                   window=window,
                                   kv_lens=kv_lens, q_chunk=ch, kv_chunk=ch)
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return out @ params["wo"], new_cache


def _trim_ctx(leaf, ctx_limit: Optional[int]):
    """Static slice of a growing cache leaf's length axis (axis 1) to the
    caller-provided live-length upper bound — the decode tail then reads
    only the live KV prefix instead of the whole max_ctx buffer."""
    if ctx_limit is None or leaf.shape[1] <= ctx_limit:
        return leaf
    return leaf[:, :ctx_limit]


def gqa_decode(params, cfg: ModelConfig, kind: str, x1, position,
               cache: Dict, kv_lens=None, ctx_limit: Optional[int] = None,
               attention_impl: str = "xla"):
    """x1: (B,1,D); cache: {"k","v"} (B,L,Hkv,hd); position scalar or (B,).
    `ctx_limit` (static) is an upper bound on kv_lens: the cache read is
    trimmed to it. `attention_impl="pallas"` (static) routes global-attention
    decode through the flash-decode kernel (scalar-prefetch trimmed grid —
    native on TPU, interpret-mode elsewhere); cases the kernel does not
    cover (no kv_lens, sliding window, non-block-multiple trimmed length)
    fall back to the jnp two-branch combine. Returns (out, new_kv)."""
    q, k, v = _proj_qkv(params, cfg, x1)
    theta = cfg.rope_theta if kind == ATTN_GLOBAL else getattr(
        cfg, "rope_theta_local", cfg.rope_theta)
    q = rope_single(q, position, theta)
    k = rope_single(k, position, theta)
    window = cfg.window if kind == ATTN_LOCAL else 0
    k_c = dequantize_kv(_trim_ctx(cache["k"], ctx_limit), cfg)
    v_c = dequantize_kv(_trim_ctx(cache["v"], ctx_limit), cfg)
    S = k_c.shape[1]
    use_pallas = (attention_impl == "pallas" and kv_lens is not None
                  and window == 0 and (S <= 256 or S % 256 == 0))
    if use_pallas:
        from repro.kernels import ops
        B = q.shape[0]
        lens = jnp.asarray(kv_lens, jnp.int32)
        # The kernel reads one contiguous buffer, so the fresh token's K/V
        # is placed at each sequence's live length (engine callers guarantee
        # kv_lens < the trimmed buffer length: the slot has append room).
        # This stages a scattered copy of the trimmed read — the fetch-
        # trimming happens inside the kernel grid, which never spans past
        # max(lens)+1 when the caller also passes a tight ctx_limit.
        idx = jnp.arange(B)
        k_all = k_c.at[idx, lens].set(k[:, 0].astype(k_c.dtype))
        v_all = v_c.at[idx, lens].set(v[:, 0].astype(v_c.dtype))
        out = ops.decode_attention(q[:, 0], k_all, v_all, lens + 1,
                                   impl="pallas")[:, None]
    else:
        out = decode_attention(q, k_c, v_c, k, v, kv_lens=kv_lens,
                               window=window, pos=jnp.asarray(position))
    out = out.reshape(x1.shape[0], 1, cfg.n_heads * cfg.head_dim)
    return out @ params["wo"], {"k": quantize_kv(k, cfg),
                                "v": quantize_kv(v, cfg)}


# --------------------------------------------------------------------------- #
# MLA (multi-head latent attention)
# --------------------------------------------------------------------------- #
def _mla_q(params, cfg: ModelConfig, x):
    B, S, _ = x.shape
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.q_lora_rank:
        q = (x @ params["w_dq"]) @ params["w_uq"]
    else:
        q = x @ params["wq"]
    q = q.reshape(B, S, cfg.n_heads, qd)
    return q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]


def mla_prefill(params, cfg: ModelConfig, x, start_pos: int,
                prefix_kv: Optional[Dict] = None, kv_lens=None,
                prefix_start: Optional[int] = None):
    """Returns (out, {"ckv","krope"}): cache stores the compressed latent
    (kv_lora_rank) + shared rope key only — the MLA memory win."""
    B, S, _ = x.shape
    pos = start_pos + jnp.arange(S)
    q_nope, q_rope = _mla_q(params, cfg, x)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)

    dkv = x @ params["w_dkv"]  # (B,S,rank+rope)
    ckv, krope = dkv[..., : cfg.kv_lora_rank], dkv[..., cfg.kv_lora_rank:]
    krope = apply_rope(krope, pos, cfg.rope_theta)
    new_cache = {"ckv": ckv, "krope": krope}

    kv_valid = None
    if prefix_kv is not None:
        P = prefix_kv["ckv"].shape[1]
        ckv_all = jnp.concatenate([prefix_kv["ckv"], ckv], axis=1)
        krope_all = jnp.concatenate([prefix_kv["krope"], krope], axis=1)
        kv_start = (start_pos - P) if prefix_start is None else prefix_start
        if kv_lens is not None:
            kv_valid = jnp.concatenate(
                [jnp.arange(P)[None, :] < kv_lens[:, None],
                 jnp.ones((B, S), bool)], axis=1)
            kv_lens = None
    else:
        ckv_all, krope_all, kv_start = ckv, krope, start_pos

    # Expand latent to per-head K/V for the compute-bound prefill (standard
    # form; the absorbed form only pays off at decode).
    k_nope = jnp.einsum("blr,rhd->blhd", ckv_all, params["w_uk"])
    vv = jnp.einsum("blr,rhd->blhd", ckv_all, params["w_uv"])
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope_all[:, :, None, :],
                                  (*k_nope.shape[:3], cfg.qk_rope_dim))], -1)
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    # pad V head_dim up to QK head_dim for the shared einsum, trim after
    kv_pos = kv_start + jnp.arange(ckv_all.shape[1])
    ch = (1 << 30) if cfg.attn_block_full else 256
    out = online_attention(q_full, k_full,
                           jnp.pad(vv, ((0, 0), (0, 0), (0, 0),
                                        (0, k_full.shape[-1] - vv.shape[-1]))),
                           pos, kv_pos, causal=True, kv_lens=kv_lens,
                           kv_valid=kv_valid, q_chunk=ch, kv_chunk=ch)
    out = out[..., : cfg.v_head_dim].reshape(B, S, cfg.n_heads * cfg.v_head_dim)
    return out @ params["wo"], new_cache


def mla_decode(params, cfg: ModelConfig, x1, position, cache: Dict,
               kv_lens=None, ctx_limit: Optional[int] = None):
    """Absorbed-matrix MLA decode: score through the latent space directly;
    attention reads c_kv (rank) + k_rope (rope_dim) only."""
    B = x1.shape[0]
    q_nope, q_rope = _mla_q(params, cfg, x1)
    q_rope = rope_single(q_rope, position, cfg.rope_theta)
    # absorb W_uk into the query: (B,1,H,nope) @ (rank,H,nope) -> (B,1,H,rank)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, params["w_uk"])

    dkv = x1 @ params["w_dkv"]
    ckv_n, krope_n = dkv[..., : cfg.kv_lora_rank], dkv[..., cfg.kv_lora_rank:]
    krope_n = rope_single(krope_n, position, cfg.rope_theta)
    new_cache = {"ckv": quantize_kv(ckv_n, cfg),
                 "krope": quantize_kv(krope_n, cfg)}
    cache = {"ckv": dequantize_kv(_trim_ctx(cache["ckv"], ctx_limit), cfg),
             "krope": dequantize_kv(_trim_ctx(cache["krope"], ctx_limit),
                                    cfg)}

    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    L = cache["ckv"].shape[1]
    s_c = (jnp.einsum("bshr,blr->bshl", q_lat, cache["ckv"],
                      preferred_element_type=jnp.float32)
           + jnp.einsum("bshd,bld->bshl", q_rope, cache["krope"],
                        preferred_element_type=jnp.float32)) * scale
    s_n = (jnp.einsum("bshr,blr->bshl", q_lat, ckv_n,
                      preferred_element_type=jnp.float32)
           + jnp.einsum("bshd,bld->bshl", q_rope, krope_n,
                        preferred_element_type=jnp.float32)) * scale
    mask_c = jnp.ones((B, 1, 1, L), bool)
    if kv_lens is not None:
        mask_c &= jnp.arange(L)[None, None, None, :] < kv_lens[:, None, None, None]
    m_c, l_c, p_c = _partial_softmax(s_c, mask_c)
    m_n, l_n, p_n = _partial_softmax(s_n, jnp.ones_like(s_n, bool))
    ctx_c = jnp.einsum("bshl,blr->bshr", p_c, cache["ckv"].astype(jnp.float32))
    ctx_n = jnp.einsum("bshl,blr->bshr", p_n, ckv_n.astype(jnp.float32))
    m = jnp.maximum(m_c, m_n)
    c_c, c_n = jnp.exp(m_c - m), jnp.exp(m_n - m)
    l = l_c * c_c + l_n * c_n
    ctx = (ctx_c * c_c[..., None] + ctx_n * c_n[..., None]) / jnp.maximum(
        l[..., None], 1e-20)
    # project latent context through W_uv per head
    out = jnp.einsum("bshr,rhd->bshd", ctx.astype(x1.dtype), params["w_uv"])
    out = out.reshape(B, 1, cfg.n_heads * cfg.v_head_dim)
    return out @ params["wo"], new_cache


# --------------------------------------------------------------------------- #
# Cross attention (whisper decoder)
# --------------------------------------------------------------------------- #
def cross_attn_skeleton(cfg: ModelConfig):
    return attn_skeleton(cfg, ATTN_GLOBAL, cross=True)


def cross_attention(params, cfg: ModelConfig, x, enc_kv: Dict):
    """x: (B,S,D); enc_kv: {"k","v"} (B,F,Hkv,hd) precomputed from encoder."""
    B, S, _ = x.shape
    q = (x @ params["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    kf = _repeat_kv(enc_kv["k"], cfg.n_heads)
    vf = _repeat_kv(enc_kv["v"], cfg.n_heads)
    F = kf.shape[1]
    out = online_attention(q, kf, vf, jnp.arange(S), jnp.arange(F),
                           causal=False)
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return out @ params["wo"]


def encode_cross_kv(params, cfg: ModelConfig, enc_out):
    B, F, _ = enc_out.shape
    k = (enc_out @ params["wk"]).reshape(B, F, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_out @ params["wv"]).reshape(B, F, cfg.n_kv_heads, cfg.head_dim)
    return {"k": k, "v": v}
