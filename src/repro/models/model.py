"""Model facade: one object per ModelConfig exposing skeleton/init and the
three program entry points the framework lowers — train hidden states,
prefill, and single-token decode — uniformly across all families."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import encdec, transformer
from .config import ModelConfig
from .layers import init_params, sds


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ----- params ------------------------------------------------------------
    def skeleton(self) -> Dict[str, Any]:
        if self.cfg.is_encoder_decoder:
            return encdec.encdec_skeleton(self.cfg)
        return transformer.lm_skeleton(self.cfg)

    def init(self, key) -> Dict[str, Any]:
        return init_params(self.skeleton(), key)

    def n_params(self) -> int:
        return sum(int(jnp.prod(jnp.array(l.shape)))
                   for l in jax.tree_util.tree_leaves(self.skeleton()))

    # ----- caches ------------------------------------------------------------
    def cache_skeleton(self, batch: int, ctx: int) -> Dict[str, Any]:
        if self.cfg.is_encoder_decoder:
            return encdec.encdec_cache_skeleton(self.cfg, batch, ctx)
        return transformer.lm_cache_skeleton(self.cfg, batch, ctx)

    def init_cache(self, batch: int, ctx: int) -> Dict[str, Any]:
        return jax.tree_util.tree_map(
            lambda l: jnp.zeros(l.shape, l.dtype),
            self.cache_skeleton(batch, ctx))

    # ----- programs ----------------------------------------------------------
    def hidden(self, params, tokens, *, frontend_embeds=None,
               remat: bool = False):
        """Training forward -> post-norm hidden states (B,S,D)."""
        if self.cfg.is_encoder_decoder:
            h, _ = encdec.encdec_hidden(params, self.cfg, tokens,
                                        frontend_embeds=frontend_embeds,
                                        remat=remat)
            return h
        h, _ = transformer.lm_hidden(params, self.cfg, tokens, mode="train",
                                     frontend_embeds=frontend_embeds,
                                     remat=remat)
        return h

    def logits(self, params, hidden):
        return transformer.lm_logits(params, self.cfg, hidden)

    def prefill(self, params, tokens, *, caches=None, start_pos: int = 0,
                frontend_embeds=None, kv_lens=None, prefix_start=None,
                logits_at=None, attention_impl: str = "xla"):
        """(logits (B,V), caches_out). caches=None: fresh turn-1 prefill;
        otherwise append-prefill against the cached prefix. See lm_prefill
        for the engine-mode prefix_start / logits_at semantics.
        `attention_impl` (static): "pallas" routes fresh global-attention
        prefill through the flash-prefill kernel; families/cases the kernel
        does not cover (MLA, sliding window, append-prefill prefix reads,
        recurrent, encdec) fall back to jnp regardless."""
        if self.cfg.is_encoder_decoder:
            return encdec.encdec_prefill(params, self.cfg, tokens,
                                         frontend_embeds=frontend_embeds,
                                         caches=caches, start_pos=start_pos,
                                         kv_lens=kv_lens)
        return transformer.lm_prefill(params, self.cfg, tokens, caches=caches,
                                      start_pos=start_pos,
                                      frontend_embeds=frontend_embeds,
                                      kv_lens=kv_lens,
                                      prefix_start=prefix_start,
                                      logits_at=logits_at,
                                      attention_impl=attention_impl)

    def decode_step(self, params, token, caches, position, kv_lens=None,
                    ctx_limit=None, attention_impl: str = "xla"):
        """(logits (B,V), cache_updates). Growing caches return the new
        token's entries only; the cache manager appends (DESIGN.md §5).
        `ctx_limit` (static) is an upper bound on kv_lens: attention cache
        reads are trimmed to it (decoder-only path; ignored for encdec).
        `attention_impl` (static): "pallas" serves GQA decode attention
        through the flash-decode kernel; "xla" keeps the jnp path. Families
        the kernel does not cover (MLA, sliding-window, recurrent, encdec)
        fall back to jnp regardless."""
        if self.cfg.is_encoder_decoder:
            return encdec.encdec_decode(params, self.cfg, token, caches,
                                        position, kv_lens=kv_lens)
        return transformer.lm_decode(params, self.cfg, token, caches,
                                     position, kv_lens=kv_lens,
                                     ctx_limit=ctx_limit,
                                     attention_impl=attention_impl)


GROWING_KEYS = ("k", "v", "ckv", "krope")


def merge_decode_cache(caches, updates):
    """Functionally fold one decode step's cache updates into the caches:
    growing entries concatenate along their length axis (grouped trees carry
    a leading layer/group dim); fixed states and cross-attention KV are
    replaced/kept. Used by simple rollout loops; the serving engine uses
    slot buffers instead (repro.engine.kvcache)."""
    if isinstance(caches, dict) and "cross" in caches and \
            "cross" not in (updates or {}):
        updates = {**updates, "cross": caches["cross"]}

    def one(path, c, u):
        names = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
        if names[-1] in GROWING_KEYS and "cross" not in names:
            grouped = names[0] in ("groups", "self")
            ax = 2 if grouped else 1
            return jnp.concatenate([c, u.astype(c.dtype)], axis=ax)
        return u

    return jax.tree_util.tree_map_with_path(one, caches, updates)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
