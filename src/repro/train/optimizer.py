"""AdamW in pure JAX. Moments are fp32 regardless of param dtype; the state
pytree mirrors the param tree so sharding specs transfer leaf-for-leaf."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def adamw_init(params) -> Dict[str, Any]:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(f32, params),
        "nu": jax.tree_util.tree_map(f32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_state_skeleton(param_skeleton) -> Dict[str, Any]:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(f32, param_skeleton),
        "nu": jax.tree_util.tree_map(f32, param_skeleton),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: AdamWConfig, grads, state, params
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else jnp.float32(1.0)
    lr = lr_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, n, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        n = cfg.b2 * n + (1 - cfg.b2) * jnp.square(g)
        mh, nh = m / b1c, n / b2c
        delta = mh / (jnp.sqrt(nh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, n

    flat_p, td = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["mu"])
    flat_n = jax.tree_util.tree_leaves(state["nu"])
    out = [upd(g, m, n, p) for g, m, n, p in zip(flat_g, flat_m, flat_n, flat_p)]
    new_p = jax.tree_util.tree_unflatten(td, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(td, [o[1] for o in out])
    new_n = jax.tree_util.tree_unflatten(td, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_m, "nu": new_n, "step": step}, metrics
