from .optimizer import AdamWConfig, adamw_init, adamw_state_skeleton, adamw_update
from .train_step import chunked_xent, make_loss_fn, make_train_step
from .data import DataConfig, SyntheticLM
from .checkpoint import latest_step, restore_checkpoint, save_checkpoint
