"""Numpy-backed checkpointing with elastic resharding.

Layout: <dir>/step_<n>/
    manifest.json   — step, flat key list, shapes/dtypes, config fingerprint
    <idx>.npy       — one file per leaf (flattened tree, keystr-indexed)

Leaves are saved UNSHARDED (gathered), so a checkpoint written from one mesh
restores onto any other — elastic scaling across restarts. Writes are
atomic (tmp dir + rename); `latest_step` scans for the newest complete
manifest, so a crash mid-write can never corrupt restore (fault tolerance
for the training path; the serving path journals conversations instead)."""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flat(tree):
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    return [(jax.tree_util.keystr(p), l) for p, l in leaves]


def save_checkpoint(ckpt_dir: str, step: int, params, opt_state,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    ckpt_dir = Path(ckpt_dir)
    tmp = ckpt_dir / f".tmp_step_{step}"
    final = ckpt_dir / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    manifest = {"step": step, "keys": [], "extra": extra or {}}
    for prefix, tree in (("params", params), ("opt", opt_state)):
        for i, (key, leaf) in enumerate(_flat(tree)):
            arr = np.asarray(jax.device_get(leaf))
            fname = f"{prefix}_{i:05d}.npy"
            np.save(tmp / fname, arr)
            manifest["keys"].append(
                {"tree": prefix, "key": key, "file": fname,
                 "shape": list(arr.shape), "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return str(final)


def latest_step(ckpt_dir: str) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = []
    for p in d.iterdir():
        if p.name.startswith("step_") and (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, params_like, opt_like,
                       shardings: Optional[Tuple[Any, Any]] = None):
    """Restore into the STRUCTURE of (params_like, opt_like) — trees of
    arrays or ShapeDtypeStructs. With `shardings` (pytrees of NamedSharding)
    leaves are placed directly onto the (possibly different) target mesh —
    the elastic-resharding path."""
    d = Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    by_tree: Dict[str, Dict[str, np.ndarray]] = {"params": {}, "opt": {}}
    for ent in manifest["keys"]:
        by_tree[ent["tree"]][ent["key"]] = np.load(d / ent["file"])

    def rebuild(like, saved, shard_tree):
        leaves = jax.tree_util.tree_leaves_with_path(like)
        shards = (jax.tree_util.tree_leaves(shard_tree)
                  if shard_tree is not None else [None] * len(leaves))
        out = []
        for (path, leaf), sh in zip(leaves, shards):
            key = jax.tree_util.keystr(path)
            if key not in saved:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = saved[key].astype(leaf.dtype)
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"target {leaf.shape}")
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jnp.asarray(arr))
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, out)

    p_sh, o_sh = shardings if shardings else (None, None)
    params = rebuild(params_like, by_tree["params"], p_sh)
    opt = rebuild(opt_like, by_tree["opt"], o_sh)
    return params, opt, manifest["extra"]
