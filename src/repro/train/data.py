"""Deterministic synthetic LM data pipeline: seeded, shardable, restartable.

Produces next-token-prediction batches from a procedural token stream (a
mixture of Zipfian unigrams and repeated n-gram motifs so the loss actually
falls during the example training runs). `step`-indexed generation means any
batch can be regenerated exactly — resuming from a checkpoint needs no data
state beyond the step counter, and each data shard draws a disjoint
substream (host-sharded input pipeline)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_a: float = 1.2
    motif_len: int = 16
    motif_prob: float = 0.35
    n_motifs: int = 256
    frontend_len: int = 0   # >0: also emit stub frontend embeddings
    d_model: int = 0


class SyntheticLM:
    def __init__(self, cfg: DataConfig, shard: int = 0, n_shards: int = 1):
        assert cfg.global_batch % n_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.n_shards = n_shards
        base = np.random.RandomState(cfg.seed)
        probs = 1.0 / np.power(np.arange(1, cfg.vocab_size + 1), cfg.zipf_a)
        self._probs = probs / probs.sum()
        self._motifs = base.randint(
            0, cfg.vocab_size, size=(cfg.n_motifs, cfg.motif_len))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        bs = cfg.global_batch // self.n_shards
        rng = np.random.RandomState(
            (cfg.seed * 1_000_003 + step * 613 + self.shard) % (2**31 - 1))
        toks = rng.choice(cfg.vocab_size, size=(bs, cfg.seq_len + 1),
                          p=self._probs).astype(np.int32)
        # splice in motifs: learnable structure
        n_splice = int(cfg.motif_prob * bs * cfg.seq_len / cfg.motif_len)
        for _ in range(n_splice):
            b = rng.randint(bs)
            pos = rng.randint(cfg.seq_len + 1 - cfg.motif_len)
            toks[b, pos: pos + cfg.motif_len] = self._motifs[
                rng.randint(cfg.n_motifs)]
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.frontend_len:
            out["frontend_embeds"] = rng.standard_normal(
                (bs, cfg.frontend_len, cfg.d_model)).astype(np.float32) * 0.02
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
