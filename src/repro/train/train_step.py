"""Training step: remat'd forward, chunked-vocab cross-entropy (never
materializes the (B,S,V) logits — the loss scans the sequence in chunks),
optional bf16 gradient compression (halves the data-parallel all-reduce
bytes), and gradient accumulation for microbatching."""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from .optimizer import AdamWConfig, adamw_update


def chunked_xent(model: Model, params, hidden, labels, chunk: int = 512):
    """hidden: (B,S,D) post-norm; labels: (B,S) int32 (-1 = masked).
    Scans sequence chunks; each step materializes only (B,chunk,V)."""
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n = (S + pad) // chunk
    hc = hidden.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_nll(h, l):
        # remat'd: backward recomputes the (B,C,V) logits/softmax from the
        # tiny hidden chunk instead of the scan saving full-vocab residuals
        # for every chunk (that residual set is B*S*V*4B — the dominant
        # training temporary without this; see EXPERIMENTS.md §Perf).
        logits = model.logits(params, h).astype(jnp.float32)  # (B,C,V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(l, 0)[..., None], axis=-1)[..., 0]
        valid = (l >= 0).astype(jnp.float32)
        nll = (lse - tgt) * valid
        return nll.sum(), valid.sum()

    def step(carry, xs):
        h, l = xs
        nll_sum, valid_sum = chunk_nll(h, l)
        loss_sum, count = carry
        return (loss_sum + nll_sum, count + valid_sum), None

    (loss_sum, count), _ = jax.lax.scan(step, (jnp.float32(0), jnp.float32(0)),
                                        (hc, lc))
    return loss_sum / jnp.maximum(count, 1.0)


def make_loss_fn(model: Model, *, remat: bool = True, loss_chunk: int = 512):
    def loss_fn(params, batch):
        h = model.hidden(params, batch["tokens"],
                         frontend_embeds=batch.get("frontend_embeds"),
                         remat=remat)
        return chunked_xent(model, params, h, batch["labels"],
                            chunk=loss_chunk)
    return loss_fn


def make_train_step(model: Model, opt_cfg: AdamWConfig, *,
                    remat: bool = True, loss_chunk: int = 512,
                    grad_accum: int = 1, compress_grads: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). With grad_accum > 1 the batch's leading dim is split into
    microbatches scanned sequentially (activation memory / grad_accum).
    compress_grads casts gradients to bf16 before the (GSPMD-inserted)
    data-parallel all-reduce — a distributed-optimization knob."""
    loss_fn = make_loss_fn(model, remat=remat, loss_chunk=loss_chunk)

    def grads_of(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if compress_grads:
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.bfloat16), grads)
        return loss, grads

    def train_step(params, opt_state, batch):
        if grad_accum > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(grad_accum, b // grad_accum, *x.shape[1:])
            micro = jax.tree_util.tree_map(split, batch)

            def acc_step(carry, mb):
                loss_a, g_a = carry
                loss, g = grads_of(params, mb)
                g_a = jax.tree_util.tree_map(
                    lambda a, b_: a + b_.astype(jnp.float32), g_a, g)
                return (loss_a + loss, g_a), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_step, (jnp.float32(0), g0),
                                            micro)
            loss = loss / grad_accum
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
        else:
            loss, grads = grads_of(params, batch)

        params, opt_state, metrics = adamw_update(opt_cfg, grads, opt_state,
                                                  params)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step
