from .conversation import Conversation, ConversationView, Turn, TurnView, view_of
from .scheduler import Placement, Scheduler, SCHEDULERS, make_scheduler
from .conserve import (ConServeRebalanceScheduler, ConServeScheduler,
                       ConServeSJFRefillScheduler)
from .baselines import AMPDScheduler, CollocatedScheduler, FullDisaggScheduler
from .signals import ClusterView, NodeState, PrefillLatencyCurve
from .runtime import (Admission, AdmissionQueue, Runtime, ServeSession,
                      SESSION_STATES, QUEUED, PREFILLING, TRANSFERRING,
                      DECODING, TOOL_WAIT, DONE)
from .provisioning import (NodeRates, WorkloadStats, min_decoders,
                           paper_configuration, prefiller_saturation_rate,
                           provision, slots_per_decoder)
from .metrics import (ConversationRecord, SLOThresholds, TurnRecord, gmean,
                      p95, per_turn_distributions, summarize)

__all__ = [n for n in dir() if not n.startswith("_")]
