from .conversation import Conversation, ConversationView, Turn, TurnView, view_of
from .scheduler import Placement, Scheduler, SCHEDULERS, make_scheduler
from .conserve import (ConServeRebalanceScheduler, ConServeScheduler,
                       ConServeSJFRefillScheduler)
from .baselines import AMPDScheduler, CollocatedScheduler, FullDisaggScheduler
from .signals import ClusterView, NodeState, PrefillLatencyCurve
from .events import (EventBus, ServeEvent, EVENT_KINDS, EV_SESSION,
                     EV_TOKENS, EV_TURN_FINISH, EV_ADMISSION_PARK,
                     EV_ADMISSION_ADMIT, EV_NODE_FAILURE, EV_RECOVERY)
from .runtime import (Admission, AdmissionQueue, Runtime, ServeSession,
                      SESSION_STATES, QUEUED, PREFILLING, TRANSFERRING,
                      DECODING, TOOL_WAIT, DONE)
from .provisioning import (NodeRates, WorkloadStats, min_decoders,
                           paper_configuration, prefiller_saturation_rate,
                           provision, slots_per_decoder)
from .metrics import (ConversationRecord, SLOThresholds, TurnRecord, gmean,
                      p95, per_turn_distributions, summarize)

__all__ = [n for n in dir() if not n.startswith("_")]
