"""Instance configuration math (§4.1).

N decoders must satisfy, at the prefiller's saturation rate R* = T_p / L_in:
    N · T_d >= R · L_d      (throughput)
    N · B   >= R · W        (memory / slots)
so the prefiller — whose load is a deterministic function of observable
input-token rate — saturates strictly before the decoder pool.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class WorkloadStats:
    mean_first_input: float      # L_in: mean turn-1 prompt tokens
    mean_decoder_volume: float   # L_d: turn-1 decode + all turn-2+ work
    mean_lifetime_s: float       # W: wall-clock incl. tool time
    mean_peak_kv_tokens: float   # per-conversation peak KV footprint


@dataclasses.dataclass(frozen=True)
class NodeRates:
    prefill_tokens_per_s: float  # T_p
    decode_tokens_per_s: float   # T_d
    kv_capacity_tokens: float    # decoder HBM budget for KV


def slots_per_decoder(rates: NodeRates, stats: WorkloadStats) -> int:
    """B: concurrent conversations one decoder can pin."""
    return max(1, int(rates.kv_capacity_tokens // max(stats.mean_peak_kv_tokens, 1)))


def prefiller_saturation_rate(rates: NodeRates, stats: WorkloadStats) -> float:
    """R* (conversations/s) at which the prefill node saturates."""
    return rates.prefill_tokens_per_s / max(stats.mean_first_input, 1.0)


def min_decoders(rate: float, rates: NodeRates, stats: WorkloadStats
                 ) -> tuple[float, float]:
    """(throughput-constrained N, memory-constrained N) at arrival rate R."""
    n_tp = rate * stats.mean_decoder_volume / rates.decode_tokens_per_s
    b = slots_per_decoder(rates, stats)
    n_mem = rate * stats.mean_lifetime_s / b
    return n_tp, n_mem


def provision(rates: NodeRates, stats: WorkloadStats,
              headroom: float = 1.0) -> int:
    """N: an integer MORE than satisfying both inequalities at R = R*, which
    places the throughput ceiling on the prefill side (§4.1)."""
    r_star = prefiller_saturation_rate(rates, stats)
    n_tp, n_mem = min_decoders(r_star * headroom, rates, stats)
    n = max(n_tp, n_mem)
    # "an integer more than satisfying": strictly exceed the bound
    return int(math.floor(n)) + 1


def paper_configuration() -> tuple[NodeRates, WorkloadStats]:
    """§5.1's measured constants: prefiller ~25k input tok/s; decoder ~1k
    output tok/s and ~300k KV tokens; ~15k input + ~1k output tokens per
    conversation. Yields R* = 1.67 conv/s and N >= 1.67 -> 3 decoders
    (the paper over-provisions to guarantee prefiller-first saturation)."""
    rates = NodeRates(prefill_tokens_per_s=25_000.0,
                      decode_tokens_per_s=1_000.0,
                      kv_capacity_tokens=300_000.0)
    stats = WorkloadStats(mean_first_input=15_000.0,
                          mean_decoder_volume=1_000.0,
                          # W consistent with the paper's N=3 satisfying
                          # eq.(2): swe-agent conversations run ~10 turns of
                          # short decodes + ~1.5s tool calls (~25s wall)
                          mean_lifetime_s=25.0,
                          mean_peak_kv_tokens=16_000.0)
    return rates, stats
