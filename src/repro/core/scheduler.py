"""Scheduler interface. The runtime (event simulator or the real JAX engine)
invokes these callbacks; policies answer *where* work runs using only the
observable ClusterView. The runtime owns all mechanism (queues, KV transfer,
batching); schedulers own only placement.

Decision points, per the paper's taxonomy:
  * conversation arrival  -> which node runs the turn-1 prefill
  * prefill completion    -> which decoder the conversation binds to
  * turn 2+ arrival       -> which node runs the append-prefill (per-turn
                             systems decide here; ConServe returns the pinned
                             decoder unconditionally)
  * conversation end      -> occupancy release (handled by runtime; hook
                             provided for stateful policies)
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Dict, List, Optional

from .conversation import ConversationView, TurnView
from .signals import ClusterView


@dataclasses.dataclass(frozen=True)
class Placement:
    node_id: int
    # Whether this placement requires moving KV state to `node_id` first
    # (remote append-prefill in per-turn systems pays a bidirectional move).
    kv_transfer: bool = False


class Scheduler(abc.ABC):
    """Base scheduler. Subclasses must be pure policies over ClusterView."""

    name = "base"

    @abc.abstractmethod
    def place_first_prefill(self, conv: ConversationView,
                            view: ClusterView) -> Placement:
        ...

    @abc.abstractmethod
    def bind_decoder(self, conv: ConversationView,
                     view: ClusterView) -> Placement:
        """Called when the turn-1 prefill finishes; the returned decoder
        receives the one-shot KV transfer and hosts the tail."""
        ...

    @abc.abstractmethod
    def place_turn(self, turn: TurnView, bound_decoder: int,
                   view: ClusterView) -> Placement:
        ...

    def on_conversation_end(self, cid: int, view: ClusterView) -> None:
        pass

    def reoffer_admission(self, cid: int, node_id: int,
                          view: ClusterView) -> Optional[Placement]:
        """Optional defer/re-offer decision point (repro.core.runtime).

        Called whenever `node_id` re-offers its admission queue (every
        release point, plus every decode-rotation chunk cut) with `cid` the
        next conversation `select_refill` picked — consulted BEFORE the
        capacity check, so a policy can drain a still-full node's queue
        toward idle peers. Return None (the default) to admit on `node_id`
        when it has capacity — FIFO, no policy involvement, which keeps
        ConServe and the baselines pure over ClusterView — or a Placement
        naming a different node to move the waiting work there instead."""
        return None

    def select_refill(self, node_id: int, waiting: List[int],
                      view: ClusterView) -> Optional[List[int]]:
        """Optional mid-tail refill ordering decision point.

        Called whenever `node_id` re-offers its admission queue — at every
        release point and at every decode-rotation chunk cut. `waiting` is
        the queue's conversation ids in FIFO order. Return None (the
        default) to refill strictly FIFO — no policy involvement, which
        keeps ConServe and the baselines pure over ClusterView — or a
        reordered list of cids naming the admission order to try instead
        (cids not in `waiting` are ignored; an empty list falls back to
        FIFO). Token streams are keyed per (cid, turn), so any refill
        ordering produces byte-identical per-conversation output — the
        hook decides WHEN work runs, never WHAT it computes."""
        return None

    # -- shared helpers -------------------------------------------------------
    @staticmethod
    def least_loaded_prefiller(view: ClusterView) -> int:
        pf = view.nodes("prefill")
        if not pf:  # collocated deployments have no dedicated prefiller
            pf = view.nodes("mixed")
        if not pf:
            # view.nodes() filters dead nodes: overlapping failures can
            # leave no prefill-capable node at all — name the condition
            # instead of a bare min() ValueError
            raise RuntimeError(
                "no healthy prefill-capable node (prefill or mixed) left "
                "in the cluster; cannot place prefill work")
        return min(pf, key=lambda n: n.queued_prefill_tokens).node_id

    @staticmethod
    def min_kv_decoder(view: ClusterView, straggler_factor: float = 0.0) -> int:
        """Decoder with lowest *active* KV occupancy (ties: fewest slots).
        With straggler_factor > 0, decoders whose observed TBT exceeds
        factor × pool median are excluded from NEW bindings — observation-
        based straggler mitigation (no prediction involved)."""
        ds = view.nodes("decode")
        if not ds:
            raise RuntimeError(
                "no healthy decoder left in the cluster; cannot bind "
                "conversations (view.nodes() filters dead nodes)")
        if straggler_factor:
            med = view.median_decoder_tbt()
            if med > 0:
                healthy = [d for d in ds
                           if d.observed_tbt_ema_s <= straggler_factor * med]
                if healthy:
                    ds = healthy
        return min(ds, key=lambda n: (n.active_kv_tokens,
                                      n.active_conversations)).node_id

    @staticmethod
    def prefix_pool_pressure(view: ClusterView, node_id: int) -> float:
        """Observed churn of a node's prefix KV pool: evictions per recorded
        hit (0.0 for an idle or perfectly-retaining pool). Built purely from
        the `pooled_prefix_*` counters the runtime maintains — a policy may
        use it to prefer nodes whose pools are NOT thrashing when placing
        turn-1 prefills of shared-preamble conversations (the ConversationView
        carries `preamble_id`, observable at arrival). No prediction of
        future reuse is involved: both inputs count events that already
        happened."""
        n = view.node(node_id)
        if n.pooled_prefix_hits <= 0:
            return float(n.pooled_prefix_evictions)
        return n.pooled_prefix_evictions / n.pooled_prefix_hits


SCHEDULERS: Dict[str, type] = {}


def register(cls):
    SCHEDULERS[cls.name] = cls
    return cls


def make_scheduler(name: str, **kw) -> Scheduler:
    return SCHEDULERS[name](**kw)
