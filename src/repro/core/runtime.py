"""The conversation-session runtime contract shared by BOTH serving backends
(the discrete-event `ClusterSimulator` and the real-JAX `EngineServer`).

The paper's claim is that conversation-level scheduling makes placement a
pure function of observable state. For that to be true BY CONTRACT rather
than by convention, both backends must present the scheduler with the same
lifecycle, the same observables, and the same overload behavior. This module
defines that contract:

* `ServeSession` — the per-conversation state machine
  (QUEUED -> PREFILLING -> TRANSFERRING -> DECODING -> TOOL_WAIT -> DONE)
  with per-state timestamps, so queue wait, transfer stall and tool time are
  measurable observations, not modeled guesses.
* `Runtime` — the serving protocol (`submit` / `run` / `results`, plus the
  admission plumbing) every backend implements; `serve()` composes them.
* Admission control with backpressure: when a target node has no free KV
  slot or insufficient headroom, the work (a conversation arrival, a
  one-shot KV binding, a remote-turn package) waits in that node's
  `AdmissionQueue` and is re-offered when occupancy frees — instead of
  crashing (the engine's old `"no free KV slots"`) or silently overcommitting
  (the simulator's old unbounded growth). Queue depth is an observable
  (`NodeState.queued_conversations`); schedulers may read it but never a
  prediction of when it will drain.

Schedulers stay pure policies over `ClusterView`: the only new decision
point is `Scheduler.reoffer_admission`, called when a node frees capacity
with work waiting — the default (None) admits in FIFO order, so ConServe
and the baselines run unmodified.

Failure contract (both backends): the conversation is the unit of recovery
because it is the unit whose state is fully OBSERVABLE — a journal of the
completed turns' token transcripts (`ConversationJournal`) plus the
deterministic per-(cid, turn) turn inputs is everything needed to rebuild a
dead node's KV by re-prefilling, through the same admission path as an
arrival. Concretely:

* a victim session REWINDS with `transition(QUEUED, t, force=True)` — the
  rewind appends to `history` (never erases it), so `time_in`/`queue_wait_s`
  remain measurements across a failure;
* the dead node's parked admissions are re-placed through the SAME scheduler
  decision point that placed them originally (`Runtime._drain_dead_node`
  below, the shared mechanism) — never silently dropped, and never re-parked
  on a node that is itself dead: with overlapping failures the cluster can
  legitimately have no healthy target, and that raises loudly instead of
  rotting in a dead queue;
* replay compute is charged to dedicated observables
  (`NodeState.replayed_prefill_tokens`, `ConversationRecord.recovered` /
  `.recovery_latency_s`), never to the victim's TTFET history.
"""
from __future__ import annotations

import abc
import dataclasses
import statistics
from collections import deque
from typing import (Any, Callable, Deque, Dict, List, Optional, Sequence,
                    Tuple)

from .events import (EV_ADMISSION_ADMIT, EV_ADMISSION_PARK, EV_NODE_JOIN,
                     EV_NODE_QUARANTINE, EV_SESSION, EventBus, ServeEvent)
from .signals import NODE_ACTIVE, NODE_DRAINING, NODE_QUARANTINED

# ----- session states --------------------------------------------------------
QUEUED = "QUEUED"              # submitted / waiting for admission
PREFILLING = "PREFILLING"      # (append-)prefill running or enqueued
TRANSFERRING = "TRANSFERRING"  # KV moving between nodes
DECODING = "DECODING"          # decode tail active on the bound node
TOOL_WAIT = "TOOL_WAIT"        # tool call in flight; KV stays pinned
DONE = "DONE"                  # final turn's last token emitted

SESSION_STATES = (QUEUED, PREFILLING, TRANSFERRING, DECODING, TOOL_WAIT, DONE)

# Legal transitions. QUEUED is re-enterable from every live state: any stage
# that needs capacity on a full node parks there until occupancy frees.
_ALLOWED: Dict[str, Tuple[str, ...]] = {
    QUEUED: (PREFILLING, TRANSFERRING, DECODING),
    PREFILLING: (TRANSFERRING, DECODING, QUEUED),
    TRANSFERRING: (PREFILLING, DECODING, QUEUED),
    DECODING: (TOOL_WAIT, DONE),
    TOOL_WAIT: (PREFILLING, TRANSFERRING, DECODING, QUEUED),
    DONE: (),
}


@dataclasses.dataclass
class ServeSession:
    """Observable lifecycle of one conversation inside a runtime.

    `history` is the full (state, entered_at) trail; timestamps come from the
    runtime's logical clock, so per-state dwell times (queue wait, transfer
    stall, tool time) are measurements of things that already happened."""
    cid: int
    arrival_s: float
    state: str = QUEUED
    node_id: Optional[int] = None  # current binding (decoder residency)
    turn_idx: int = 0
    history: List[Tuple[str, float]] = dataclasses.field(default_factory=list)
    # observer hook fired from INSIDE transition() — the event bus reads the
    # state machine at its own transition point, never a mirrored copy.
    # Called as notify(session, prev_state, new_state, t) after the history
    # entry lands; observers must not mutate the session.
    notify: Optional[Callable[["ServeSession", str, str, float], None]] = \
        dataclasses.field(default=None, repr=False, compare=False)

    def __post_init__(self):
        if not self.history:
            self.history.append((self.state, self.arrival_s))

    def transition(self, state: str, t: float, *, force: bool = False):
        """Enter `state` at time `t`. Raises on an illegal transition unless
        `force` (failure recovery legitimately rewinds a session).

        Entry timestamps are clamped monotone non-decreasing against the
        session's own history. Normal serving already satisfies this (each
        stage's stamp is at or after the previous stage's); a failure REWIND
        interleaves with logically-later completions — e.g. a staged decode
        stamped at its future prefill-completion time when the replica dies
        just before that instant — and the clamp keeps every dwell
        (`time_in`) a non-negative measurement rather than erasing history."""
        if state == self.state:
            return
        if not force and state not in _ALLOWED[self.state]:
            raise RuntimeError(
                f"illegal session transition for cid {self.cid}: "
                f"{self.state} -> {state} (allowed: "
                f"{', '.join(_ALLOWED[self.state]) or 'none'})")
        prev = self.state
        self.state = state
        self.history.append((state, max(t, self.history[-1][1])))
        if self.notify is not None:
            self.notify(self, prev, state, self.history[-1][1])

    def time_in(self, state: str, now: Optional[float] = None) -> float:
        """Total seconds spent in `state` over the session's closed history
        segments (plus the open segment up to `now`, when given)."""
        total = 0.0
        for (s, t0), (_, t1) in zip(self.history, self.history[1:]):
            if s == state:
                total += t1 - t0
        if self.history and self.history[-1][0] == state and now is not None:
            total += max(now - self.history[-1][1], 0.0)
        return total

    @property
    def queue_wait_s(self) -> float:
        """Accumulated admission wait — the backpressure signal overload
        benchmarks record."""
        return self.time_in(QUEUED)

    @property
    def done(self) -> bool:
        return self.state == DONE


# ----- admission -------------------------------------------------------------
@dataclasses.dataclass
class Admission:
    """One unit of work waiting for capacity on a node: a conversation
    arrival, a one-shot KV binding, or a remote-turn package. `ready` is
    invoked with the ADMITTING node id (the scheduler's re-offer hook may
    move a parked admission to a different node before it runs). `kind`
    records which scheduler decision point placed the work, so a runtime
    that must re-place a parked admission (e.g. its node died) asks the
    same decision point again."""
    cid: int
    need_tokens: int           # KV tokens the work lands with (headroom ask)
    ready: Callable[[int], None]
    kind: str = "bind"         # "arrival" | "bind" | "turn"
    # set the first time this admission parks: one admission counts at most
    # once toward n_deferred_admissions even if a reoffer policy later
    # moves it to another node that also parks it
    deferred: bool = False
    # prefill-COMPUTE tokens this work will actually run (the backlog charge
    # feeding `queued_prefill_tokens`). None = need_tokens. They differ when
    # a shared prefix is already resident in the target node's prefix KV
    # pool: the slot still lands with the FULL context (need_tokens — the
    # headroom/fit ask is unchanged), but only the delta past the pooled
    # prefix is computed. Set from an OBSERVED pool hit at offer time, never
    # from a prediction of what the pool might hold later.
    charge_tokens: Optional[int] = None

    @property
    def charge(self) -> int:
        return (self.need_tokens if self.charge_tokens is None
                else self.charge_tokens)


class AdmissionQueue:
    """Per-node queue of admissions waiting for a free KV slot / headroom.
    FIFO by default; `Scheduler.select_refill` may name a different cid to
    admit first (mid-tail rotation refill), so arbitrary-position peek and
    removal are part of the contract."""

    def __init__(self, node_id: int):
        self.node_id = node_id
        self._q: Deque[Admission] = deque()

    def __len__(self) -> int:
        return len(self._q)

    def push(self, adm: Admission):
        self._q.append(adm)

    def cids(self) -> List[int]:
        """Waiting conversation ids, FIFO order (the select_refill input)."""
        return [a.cid for a in self._q]

    def admissions(self, kind: Optional[str] = None) -> List[Admission]:
        """Waiting admissions (optionally filtered by kind), FIFO order —
        read-only view for accounting checks (strict_accounting asserts
        each node's backlog observables against exactly this state)."""
        return [a for a in self._q if kind is None or a.kind == kind]

    def peek(self, cid: int) -> Admission:
        """The first waiting admission for `cid` (a conversation has at most
        one admission in flight at a time)."""
        for a in self._q:
            if a.cid == cid:
                return a
        raise KeyError(f"cid {cid} is not waiting on node {self.node_id}")

    def remove(self, cid: int) -> Admission:
        adm = self.peek(cid)
        self._q.remove(adm)
        return adm

    def drain(self) -> List[Admission]:
        out = list(self._q)
        self._q.clear()
        return out


# ----- journal ---------------------------------------------------------------
class ConversationJournal:
    """Per-conversation transcript journal: the token stream each COMPLETED
    turn fed into the KV cache, keyed (cid, turn_idx). Together with the
    deterministic turn inputs this is sufficient to rebuild a conversation's
    exact KV state on any replica by re-prefilling — deterministic replay,
    the paper's recovery mechanism, with zero prediction involved.

    The engine records each turn's SAMPLED stream here at turn completion
    (the stream is ``[prefill argmax] + decoded tokens``, length n+1; the
    last sampled token of a turn is never fed back, so the KV-fed slice is
    ``stream[:-1]``). The simulator's journal is implicit — its cost model
    tracks token COUNTS, so `_recover`'s context arithmetic plays the same
    role — but both backends share the contract: completed turns are
    journaled, in-flight turns are not (their partial output is discarded
    and re-decoded, which determinism makes byte-identical).

    Entries are dropped at conversation DONE to bound memory to live work."""

    def __init__(self):
        self._streams: Dict[Tuple[int, int], Any] = {}

    def record(self, cid: int, turn_idx: int, stream: Sequence[int]):
        """Journal a completed turn's full sampled stream. Re-recording the
        same turn (it completed once; recovery replays only in-flight turns)
        would mean non-deterministic replay — kept loud."""
        key = (cid, turn_idx)
        if key in self._streams:
            raise RuntimeError(
                f"turn {turn_idx} of conversation {cid} journaled twice — "
                f"a completed turn must never re-run")
        self._streams[key] = list(stream)

    def fed_tokens(self, cid: int, turn_idx: int) -> List[int]:
        """The tokens turn `turn_idx` fed into the KV cache (the sampled
        stream minus its final token, which was never appended)."""
        return self._streams[(cid, turn_idx)][:-1]

    def n_completed(self, cid: int) -> int:
        """Completed (journaled) turns for `cid`. Turns complete in order,
        so this is also the index of the first un-journaled turn."""
        return sum(1 for (c, _) in self._streams if c == cid)

    def drop(self, cid: int):
        for key in [k for k in self._streams if k[0] == cid]:
            del self._streams[key]


# ----- prefix KV pool: the one shared eviction rule --------------------------
def prefix_eviction_order(entries: Dict[Any, Any]) -> List[Any]:
    """Eviction order for a node's prefix KV pool, shared by BOTH backends so
    the pools age identically under one contract.

    The rule is observation-only (Astraea's argument, PAPERS.md): evict the
    entry with the FEWEST observed reuse hits first, ties broken
    least-recently-hit (LRU over measured hits, `last_use` is a monotone use
    sequence number) — never a predicted popularity. Entries with live
    references (`refs > 0`: a prefill is reading the rows right now) are
    pinned and excluded entirely; callers must REFUSE to make room rather
    than evict pinned rows out from under an in-flight program.

    `entries` maps pool key -> entry with observable counters `hits`,
    `last_use`, `refs`. Returns the evictable keys, first-to-evict first.
    """
    evictable = [(e.hits, e.last_use, k) for k, e in entries.items()
                 if e.refs == 0]
    evictable.sort(key=lambda t: (t[0], t[1]))
    return [k for _, _, k in evictable]


@dataclasses.dataclass
class PrefixPoolEntry:
    """One immutable pooled prefix. In the engine, `caches` holds the device
    rows shaped exactly like `slice_slot_prefix`'s output ((…, 1, ctx, …)
    growing leaves, (…, 1, …) fixed states), zero-masked beyond `length` so
    the padded tail carries no slot-specific stale bytes; the simulator
    models only the token volume and stores None. `hits`/`last_use` are the
    OBSERVED reuse counters the eviction rule orders on; `refs` pins the
    entry while a prefill is reading it."""
    key: Any
    caches: Any
    length: int           # live prefix tokens
    ctx: int              # padded ctx bucket the rows were exported at
    hits: int = 0
    last_use: int = 0
    refs: int = 0


class PrefixKVPool:
    """Node-level pool of immutable shared-prefix KV rows — ONE container
    for both backends (the engine keys by token-content hash and stores
    device rows; the simulator keys by preamble identity and stores token
    volume only), so the pools age identically under the shared eviction
    rule.

    A third cache ownership class: rows owned by NO slot — populated the
    first time a preamble is prefilled, read (never written) by any number
    of later turn-1 prefills on the same node. Capacity is a budget in
    live prefix tokens, SEPARATE from the slot cache's kv_capacity, so
    `kv_headroom_tokens` keeps meaning slot-landable work. Eviction is the
    shared `prefix_eviction_order` rule (fewest observed hits, ties
    least-recently-hit, pinned entries untouchable): when evicting every
    unpinned entry still cannot make room, `put` REFUSES (returns False)
    rather than evict pinned rows out from under a reader."""

    def __init__(self, capacity_tokens: int):
        self.capacity_tokens = int(capacity_tokens)
        self.entries: Dict[Any, PrefixPoolEntry] = {}
        self._seq = 0  # monotone use counter (LRU tie-break clock)
        self.total_hits = 0
        self.n_evictions = 0

    # ----- observables -------------------------------------------------------
    @property
    def pooled_tokens(self) -> int:
        return sum(e.length for e in self.entries.values())

    @property
    def n_entries(self) -> int:
        return len(self.entries)

    # ----- reads -------------------------------------------------------------
    def contains(self, key) -> bool:
        return key in self.entries

    def get(self, key) -> Optional[PrefixPoolEntry]:
        """Look up pooled rows and RECORD the reuse: hits and last_use are
        the observed counters eviction orders on, so a lookup that feeds a
        prefill must come through here (use `contains` for side-effect-free
        checks)."""
        e = self.entries.get(key)
        if e is None:
            return None
        self._seq += 1
        e.hits += 1
        e.last_use = self._seq
        self.total_hits += 1
        return e

    # ----- pinning -----------------------------------------------------------
    def pin(self, key):
        self.entries[key].refs += 1

    def unpin(self, key):
        e = self.entries[key]
        if e.refs <= 0:
            raise RuntimeError(
                f"prefix pool entry {key} unpinned more times than pinned")
        e.refs -= 1

    # ----- writes ------------------------------------------------------------
    def put(self, key, caches, length: int, ctx: int) -> bool:
        """Install pooled rows for `key`, evicting by the shared observed-
        reuse rule until the token budget fits. Returns False (and pools
        nothing) when the entry can never fit or only pinned entries could
        make room. Re-putting an existing key is a no-op (the rows are
        immutable — first write wins)."""
        if key in self.entries:
            return True
        if length > self.capacity_tokens:
            return False
        while self.pooled_tokens + length > self.capacity_tokens:
            order = prefix_eviction_order(self.entries)
            if not order:
                return False  # everything left is pinned — refuse, don't rip
            victim = self.entries.pop(order[0])
            self.n_evictions += 1
            del victim
        self._seq += 1
        self.entries[key] = PrefixPoolEntry(
            key=key, caches=caches, length=int(length), ctx=int(ctx),
            last_use=self._seq)
        return True

    def invalidate_all(self):
        """Node failure: pooled rows die with the node's slot cache (same
        `invalidate_all` moment). Entries are dropped so a recovered
        conversation re-populates through the normal miss path instead of
        dangling a reference to dead device buffers; cumulative counters
        (hits/evictions) survive — they count events that already
        happened."""
        self.entries.clear()


class Runtime(abc.ABC):
    """Serving contract both backends implement. Subclasses provide:

    * `sched` (a `Scheduler`), `view` (a `ClusterView`),
    * `sessions: Dict[int, ServeSession]`,
    * `_admission: Dict[int, AdmissionQueue]` (one per node),
    * `_can_admit(node_id, adm)` — the backend's ground-truth capacity check
      (engine: a free KV slot; simulator: a free slot AND token headroom).

    The base class owns the admission/backpressure mechanism so overload
    behaves identically at both scales; schedulers only ever see the
    observable consequences (queue depth, occupancy) through `ClusterView`.
    """

    sessions: Dict[int, ServeSession]
    _admission: Dict[int, "AdmissionQueue"]
    # how many admissions were ever deferred (parked) — a structural
    # backpressure signal independent of measured wall time
    n_deferred_admissions: int = 0
    # lifecycle: False while the runtime accepts submissions (before and
    # DURING the event loop — staged arrivals inject mid-flight); True once
    # run() completed or close() was called, after which submit() raises
    _closed: bool = False
    # observed-straggler quarantine config (None disables the trigger; both
    # backends expose these as constructor parameters). A node flips
    # ACTIVE -> QUARANTINED when its observed_tbt_ema_s exceeds
    # quarantine_k × the fleet median for quarantine_window consecutive
    # observed decode chunks, and requalifies (-> DRAINING -> ACTIVE) once
    # it falls back below quarantine_rejoin_k × median (defaults to
    # quarantine_k) for the same window. Every quantity involved is an
    # observation the runtime already owns — never a failure prediction.
    quarantine_k: Optional[float] = None
    quarantine_window: int = 3
    quarantine_rejoin_k: Optional[float] = None

    # ----- protocol ----------------------------------------------------------
    @abc.abstractmethod
    def submit(self, convs) -> "Runtime":
        """Register conversations (records + sessions) and schedule their
        arrival events. Legal before and DURING the event loop (staged
        arrival injection: an arrival timestamp already in the logical past
        is clamped to now); raises once the runtime is closed. Returns self
        for chaining."""

    @abc.abstractmethod
    def run(self) -> "Runtime":
        """Drain the event loop, then CLOSE the runtime (late submissions
        raise). Returns self for chaining."""

    @abc.abstractmethod
    def run_pending(self, max_events: Optional[int] = None) -> int:
        """Incremental drive: pop up to `max_events` pending events (all of
        them when None) WITHOUT closing the runtime, so staged submissions
        may keep arriving between calls — the live gateway's drive loop.
        Returns the number of events executed."""

    @abc.abstractmethod
    def results(self) -> list:
        """Completed `ConversationRecord`s."""

    def serve(self, convs) -> list:
        """The one-call contract: submit + run + results."""
        return self.submit(convs).run().results()

    # ----- lifecycle ---------------------------------------------------------
    @property
    def now_s(self) -> float:
        """The runtime's current logical-clock instant. Backends override
        (engine `_now`, simulator `now`); shared read path for front ends
        (gateway, chaos driver) that arm time-scheduled faults."""
        raise NotImplementedError(
            f"{type(self).__name__} does not expose now_s")

    @property
    def runtime_state(self) -> str:
        """"accepting" while submissions are legal, "closed" after."""
        return "closed" if self._closed else "accepting"

    def close(self):
        """Finalize: no further submissions are accepted. run() calls this
        after draining; a gateway calls it at drain time."""
        self._closed = True

    def _assert_accepting(self):
        """Loud guard for every submit(): a submission after run() completed
        would push arrival events onto a heap nothing drains — on the engine
        backend that used to be SILENTLY inert (sessions registered, nothing
        ever served). Name the runtime state instead."""
        if self._closed:
            raise RuntimeError(
                f"late submission rejected: {type(self).__name__} runtime "
                f"state is '{self.runtime_state}' — run() already completed "
                f"(or close() was called) and drained the event loop, so "
                f"the arrival would never execute. Submit before or during "
                f"run(), or drive staged arrivals through run_pending() / "
                f"repro.serve.ServeGateway.")

    # ----- event bus ---------------------------------------------------------
    @property
    def bus(self) -> EventBus:
        """The runtime's event bus, created on first access. Hot paths guard
        with `_publish`, which never creates the bus — a runtime nobody
        subscribed to pays one dict lookup per potential event."""
        b = self.__dict__.get("_bus")
        if b is None:
            b = self.__dict__["_bus"] = EventBus()
        return b

    def _publish(self, event_kind: str, t: float, *,
                 cid: Optional[int] = None, turn_idx: Optional[int] = None,
                 node_id: Optional[int] = None, **data):
        # first param deliberately not named "kind": admission events carry
        # a "kind" payload key (the Admission.kind decision point) in **data
        bus = self.__dict__.get("_bus")
        if bus is not None and bus.wants(event_kind):
            bus.publish(ServeEvent(kind=event_kind, t=t, cid=cid,
                                   turn_idx=turn_idx, node_id=node_id,
                                   data=data))

    def _notify_session(self, sess: ServeSession, prev: str, state: str,
                        t: float):
        """ServeSession.notify target: republish the state machine's own
        transition (the hook fires inside transition(), so `sess` IS the
        owned state at that instant)."""
        self._publish(EV_SESSION, t, cid=sess.cid, turn_idx=sess.turn_idx,
                      node_id=sess.node_id, state=state, prev=prev)

    # ----- admission mechanism ----------------------------------------------
    @abc.abstractmethod
    def _can_admit(self, node_id: int, adm: Admission) -> bool:
        ...

    def _never_fits(self, node_id: int, adm: Admission) -> bool:
        """True when `adm` can NEVER fit on `node_id` no matter how much
        occupancy frees (backend capacity bound). Backends override; the
        base conservatively says False. Used to veto a reoffer policy's
        move: work legally waiting on its origin must not be relocated
        somewhere the loud never-fits check would kill the serve."""
        return False

    def _on_reoffer_move(self, adm: Admission, from_node: int,
                         to_node: int) -> None:
        """Hook: a parked admission is being MOVED from `from_node`'s queue
        to `to_node` by a `reoffer_admission` policy. Backends that maintain
        per-node backlog observables derived from parked work (the engine's
        `queued_prefill_tokens`) move them here, at the instant the work
        changes queues — moving them later (e.g. when the admission finally
        runs) lets the counter sit on the wrong node for the whole parked
        interval, which is exactly the drift strict accounting rejects."""

    def _make_session(self, cid: int, arrival_s: float) -> ServeSession:
        sess = ServeSession(cid=cid, arrival_s=arrival_s,
                            notify=self._notify_session)
        self.sessions[cid] = sess
        return sess

    # ----- replica lifecycle (observed-straggler quarantine) -----------------
    @property
    def _lifecycle_streaks(self) -> Dict[int, Tuple[int, int]]:
        """Per-node (consecutive-above, consecutive-below) chunk counters for
        the quarantine trigger — lazily created like the bus so backends
        need no ctor changes. Counters of observed chunk comparisons that
        already happened, nothing predictive."""
        d = self.__dict__.get("_lc_streaks")
        if d is None:
            d = self.__dict__["_lc_streaks"] = {}
        return d

    def _node_has_inflight(self, node_id: int) -> bool:
        """True while `node_id` still runs or holds in-flight work (decode
        tails, queued prefill, bound sessions). Backends override; the base
        says False so QUARANTINED -> ACTIVE requalification is immediate."""
        return False

    def _observe_chunk_tbt(self, node_id: int, now: float):
        """Lifecycle trigger, called by both backends immediately after every
        `observed_tbt_ema_s` update (one observed decode chunk). Compares the
        node's own EMA against the median of its live ACTIVE decode-capable
        peers — both sides of the comparison are maintained observations —
        and advances the ACTIVE -> QUARANTINED -> DRAINING -> ACTIVE machine.

        Known (documented) limit of observation-only rejoin: a quarantined
        node with no in-flight tails produces no new chunk observations, so
        its EMA can never be observed to recover and it stays QUARANTINED
        until revived externally — the trigger never invents a probe."""
        if self.quarantine_k is None:
            return
        st = self.view.node(node_id)
        if not st.alive or st.observed_tbt_ema_s <= 0:
            return
        peers = [n.observed_tbt_ema_s for n in self.view.nodes()
                 if n.role in ("decode", "mixed") and n.node_id != node_id
                 and n.observed_tbt_ema_s > 0]
        if not peers:
            return  # no healthy peer baseline to compare against
        med = statistics.median(peers)
        if med <= 0:
            return
        streaks = self._lifecycle_streaks
        above, below = streaks.get(node_id, (0, 0))
        rejoin_k = (self.quarantine_k if self.quarantine_rejoin_k is None
                    else self.quarantine_rejoin_k)
        if st.lifecycle == NODE_ACTIVE:
            above = above + 1 if st.observed_tbt_ema_s > \
                self.quarantine_k * med else 0
            streaks[node_id] = (above, 0)
            if above >= self.quarantine_window:
                streaks[node_id] = (0, 0)
                self._quarantine_node(node_id, now, st.observed_tbt_ema_s,
                                      med)
        elif st.lifecycle == NODE_QUARANTINED:
            below = below + 1 if st.observed_tbt_ema_s <= rejoin_k * med \
                else 0
            streaks[node_id] = (0, below)
            if below >= self.quarantine_window:
                streaks[node_id] = (0, 0)
                if self._node_has_inflight(node_id):
                    st.lifecycle = NODE_DRAINING
                else:
                    self._rejoin_node(node_id, now,
                                      reason="from_quarantine")
        # DRAINING: requalified already — only waiting on resident tails;
        # _maybe_finish_draining (called at every release point) completes it

    def _quarantine_node(self, node_id: int, now: float, ema: float,
                         med: float):
        """Flip `node_id` out of the schedulable set: it takes no new
        placements or refills (ClusterView.nodes() hides it; _offer refuses
        it), its parked admissions re-place to peers through the same
        decision points a failure drain uses, and its in-flight tails keep
        running — they are the observation source the rejoin rule needs."""
        st = self.view.node(node_id)
        st.lifecycle = NODE_QUARANTINED
        log = getattr(self, "log", None)
        if log is not None:
            log.append(
                f"t={now:.3f} QUARANTINE node {node_id}: observed TBT EMA "
                f"{ema:.6f}s > {self.quarantine_k}x fleet median "
                f"{med:.6f}s over {self.quarantine_window} chunks")
        self._publish(EV_NODE_QUARANTINE, now, node_id=node_id,
                      observed_tbt_ema_s=ema, fleet_median_tbt_s=med,
                      k=self.quarantine_k)
        self._drain_dead_node(node_id, now)

    def _rejoin_node(self, node_id: int, now: float, *, reason: str):
        """`node_id` (re)enters ACTIVE service — revival of a dead replica
        (`reason="from_dead"`) or an observed-EMA recovery out of quarantine
        (`reason="from_quarantine"`). Publishes `node_join`, then pumps
        EVERY active node's admission queue so parked work lands on the
        rejoined capacity immediately."""
        st = self.view.node(node_id)
        st.lifecycle = NODE_ACTIVE
        self._lifecycle_streaks.pop(node_id, None)
        log = getattr(self, "log", None)
        if log is not None:
            log.append(f"t={now:.3f} JOIN node {node_id} ({reason})")
        self._publish(EV_NODE_JOIN, now, node_id=node_id, reason=reason)
        self._pump_all(now)

    def _maybe_finish_draining(self, node_id: int, now: float):
        """Release-point hook: a DRAINING node whose last in-flight tail
        just left re-activates."""
        st = self.view.node(node_id)
        if (st.alive and st.lifecycle == NODE_DRAINING
                and not self._node_has_inflight(node_id)):
            self._rejoin_node(node_id, now, reason="from_quarantine")

    def _pump_all(self, now: float):
        """Pump every schedulable node's admission queue — the rejoin path:
        a reoffer policy may now move parked work onto the fresh node."""
        for nid in self._admission:
            st = self.view.node(nid)
            if st.alive and st.lifecycle == NODE_ACTIVE:
                self._pump(nid, now)

    # ----- failure mechanism -------------------------------------------------
    def _replace_admission(self, adm: Admission, now: float) -> Optional[int]:
        """Re-place one admission drained from a dead node's queue through
        the SAME scheduler decision point that placed it originally (`kind`
        records which). Return the new target node id, or None when the
        backend re-dispatched the work some other way (e.g. re-planning a
        turn placement from scratch). Backends with failure semantics
        override; the base raises so a backend can't silently drop work."""
        raise NotImplementedError(
            f"{type(self).__name__} drained a dead node's admission queue "
            f"but implements no _replace_admission")

    def _drain_dead_node(self, node_id: int, now: float):
        """Shared failure/quarantine semantics: an unschedulable node's
        parked admissions would never be pumped — drain them and re-place
        each via `_replace_admission`, guarding the result. (The name keeps
        the failure contract's original entry point; quarantine reuses the
        identical mechanism on a still-alive node.) With overlapping
        failures the chosen target can itself be dead or quarantined, or
        the cluster may have no healthy candidate at all (the scheduler
        helpers raise); all must fail loudly here instead of re-parking
        work on an unschedulable node."""
        st = self.view.node(node_id)
        for adm in self._admission[node_id].drain():
            st.queued_conversations -= 1
            target = self._replace_admission(adm, now)
            if target is None:
                continue
            tgt = self.view.node(target)
            if not tgt.alive:
                raise RuntimeError(
                    f"re-placement of conversation {adm.cid} "
                    f"({adm.kind}) off dead node {node_id} chose node "
                    f"{target}, which is also dead; schedulers must place "
                    f"on live nodes only")
            if tgt.lifecycle != NODE_ACTIVE:
                raise RuntimeError(
                    f"re-placement of conversation {adm.cid} "
                    f"({adm.kind}) off node {node_id} chose node "
                    f"{target}, which is {tgt.lifecycle}; schedulers must "
                    f"place on ACTIVE nodes only")
            self._on_reoffer_move(adm, node_id, target)
            self._offer(target, adm, now)

    def _offer(self, node_id: int, adm: Admission, now: float) -> bool:
        """Admit `adm` on `node_id` immediately if it has capacity and no one
        is already waiting (FIFO fairness); otherwise park it in the node's
        admission queue and flip the session to QUEUED. Returns True when the
        work ran now."""
        target = self.view.node(node_id)
        if not target.alive:
            # work offered to a dead node would park in a queue nothing ever
            # pumps — every placement path must name a live node
            raise RuntimeError(
                f"admission for conversation {adm.cid} ({adm.kind}) offered "
                f"to dead node {node_id}; placements must name a live node")
        if target.lifecycle != NODE_ACTIVE:
            # a quarantined/draining node takes no new placements; parked
            # work there would wait on a node that refuses refills
            raise RuntimeError(
                f"admission for conversation {adm.cid} ({adm.kind}) offered "
                f"to {target.lifecycle} node {node_id}; placements must "
                f"name an ACTIVE node")
        q = self._admission[node_id]
        # evaluate capacity even when others are waiting: _can_admit is also
        # where work that can NEVER fit raises — that must happen at offer
        # time, not later from an unrelated conversation's release event
        fits = self._can_admit(node_id, adm)
        if len(q) == 0 and fits:
            self._publish(EV_ADMISSION_ADMIT, now, cid=adm.cid,
                          node_id=node_id, kind=adm.kind,
                          need_tokens=adm.need_tokens)
            adm.ready(node_id)
            return True
        q.push(adm)
        self.view.node(node_id).queued_conversations += 1
        self._publish(EV_ADMISSION_PARK, now, cid=adm.cid, node_id=node_id,
                      kind=adm.kind, need_tokens=adm.need_tokens)
        # structural backpressure count (independent of measured timings);
        # an admission re-parked by a reoffer move does not count twice
        if not adm.deferred:
            adm.deferred = True
            self.n_deferred_admissions = getattr(
                self, "n_deferred_admissions", 0) + 1
        sess = self.sessions.get(adm.cid)
        if sess is not None:
            sess.transition(QUEUED, now)
        return False

    def _pump(self, node_id: int, now: float):
        """Re-offer parked work on `node_id` — at every release point, and
        (on rotating backends) at every decode chunk cut. Two scheduler
        decision points, both defaulting to the unmodified FIFO behavior:

        * `select_refill` picks WHICH waiting conversation to try first
          (default: the queue head);
        * `reoffer_admission` may move that admission to another node
          (default: stay). It is consulted before the capacity check, so a
          policy can drain a still-full node's queue toward idle peers.

        Admission stops at the first selected conversation this node cannot
        take (head-of-line semantics under FIFO; a reordering policy picks
        its own head). A non-ACTIVE node never refills (its queue was
        drained at the transition; the guard keeps release-point callers
        honest)."""
        q = self._admission[node_id]
        st = self.view.node(node_id)
        if not st.alive or st.lifecycle != NODE_ACTIVE:
            return
        while len(q):
            cids = q.cids()
            order = self.sched.select_refill(node_id, list(cids), self.view)
            cid = cids[0]
            if order:
                cid = next((c for c in order if c in cids), cids[0])
            adm = q.peek(cid)
            pl = self.sched.reoffer_admission(adm.cid, node_id, self.view)
            if pl is not None and pl.node_id != node_id \
                    and not self._never_fits(pl.node_id, adm):
                # the hook sees only (cid, node, view) — the mechanism, not
                # the policy, guards against moving work somewhere it could
                # never fit (heterogeneous capacities)
                q.remove(cid)
                st.queued_conversations -= 1
                self._on_reoffer_move(adm, node_id, pl.node_id)
                self._offer(pl.node_id, adm, now)
                continue
            if not self._can_admit(node_id, adm):
                break
            q.remove(cid)
            st.queued_conversations -= 1
            self._publish(EV_ADMISSION_ADMIT, now, cid=adm.cid,
                          node_id=node_id, kind=adm.kind,
                          need_tokens=adm.need_tokens)
            adm.ready(node_id)

    # ----- shared observables -----------------------------------------------
    def queue_waits(self) -> Dict[int, float]:
        """Per-conversation admission wait (seconds) — the backpressure cost
        overload benchmarks and capacity planning read."""
        return {cid: s.queue_wait_s for cid, s in self.sessions.items()}
