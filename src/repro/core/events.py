"""Event bus for the serving runtimes: subscriber hooks fired from the
runtime's OWN transition points.

The paper's observability stance extends to streaming: everything a live
front end can report is a read of state the runtime already owns — the
session state machine's transitions, the decode rotation's per-(cid, turn)
token streams (`_TurnTask.stream` on the engine; turn-granularity counts on
the simulator), admission parks/admits, node failures and recovery rewinds.
The bus therefore carries REFERENCES to those moments, never a second
bookkeeping path: no counter lives here, and a runtime with zero
subscribers pays one dict lookup per potential publish
(`Runtime._publish` checks `EventBus.wants` before building the event).

Event kinds (the `data` payload names state owned elsewhere):

* ``session``      — a `ServeSession.transition` fired:
                     ``{"state", "prev"}`` (+ cid / turn_idx / node_id).
* ``tokens``       — decode emission. Engine: ``{"tokens": [ids...],
                     "per_token_s"}`` per chunk share, with the turn's
                     opening prefill-argmax token published at stage time —
                     concatenated per (cid, turn) the payloads reproduce
                     `_TurnTask.stream` byte-for-byte. Simulator:
                     ``{"n_tokens": N}`` once per completed turn (the sim
                     emits at turn granularity; it has no token bytes).
* ``turn_finish``  — a turn completed and was recorded
                     (``{"n_output_tokens"}``).
* ``admission_park``  — work parked in a node's admission queue
                     (``{"kind", "need_tokens"}``).
* ``admission_admit`` — a previously parked admission ran
                     (``{"kind", "need_tokens"}``).
* ``node_failure`` — a node died (``{"n_victims"}``).
* ``node_join``    — a node (re)entered ACTIVE service: revival of a dead
                     replica or an observed-EMA recovery out of quarantine
                     (``{"reason": "from_dead" | "from_quarantine"}``).
* ``node_quarantine`` — a node's observed_tbt_ema_s exceeded k× the fleet
                     median over the configured window and it left the
                     schedulable set (``{"observed_tbt_ema_s",
                     "fleet_median_tbt_s", "k"}``).
* ``recovery``     — a conversation REWOUND for deterministic replay: every
                     token already published for the named in-flight turn is
                     stale and will re-stream byte-identically. Subscribers
                     holding per-(cid, turn) accumulations must reset that
                     key (the gateway does); completed turns never rewind.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

# ----- event kinds -----------------------------------------------------------
EV_SESSION = "session"
EV_TOKENS = "tokens"
EV_TURN_FINISH = "turn_finish"
EV_ADMISSION_PARK = "admission_park"
EV_ADMISSION_ADMIT = "admission_admit"
EV_NODE_FAILURE = "node_failure"
EV_NODE_JOIN = "node_join"
EV_NODE_QUARANTINE = "node_quarantine"
EV_RECOVERY = "recovery"

EVENT_KINDS = (EV_SESSION, EV_TOKENS, EV_TURN_FINISH, EV_ADMISSION_PARK,
               EV_ADMISSION_ADMIT, EV_NODE_FAILURE, EV_NODE_JOIN,
               EV_NODE_QUARANTINE, EV_RECOVERY)


@dataclasses.dataclass(frozen=True)
class ServeEvent:
    """One observed runtime moment. `t` is the runtime's LOGICAL clock at the
    transition point (both backends run logical time); `data` carries the
    kind-specific payload documented in the module docstring."""
    kind: str
    t: float
    cid: Optional[int] = None
    turn_idx: Optional[int] = None
    node_id: Optional[int] = None
    data: Dict[str, Any] = dataclasses.field(default_factory=dict)


class EventBus:
    """Synchronous fan-out of `ServeEvent`s to subscribers.

    Subscribers are plain callables invoked inline at the transition point,
    so a subscriber observes state exactly as it was at the moment the
    runtime owned it (no queueing, no reordering). A subscriber must not
    mutate runtime state — the bus is a read path.

    `wants(kind)` is the zero-cost guard runtimes check before building an
    event: with no subscriber for `kind` (and no wildcard subscriber) the
    hot paths skip payload construction entirely.
    """

    def __init__(self):
        # kind -> subscriber list; the None key holds wildcard subscribers
        self._subs: Dict[Optional[str], List[Callable[[ServeEvent], None]]] = {}
        self.n_published = 0

    def subscribe(self, fn: Callable[[ServeEvent], None],
                  kinds: Optional[Sequence[str]] = None
                  ) -> Callable[[], None]:
        """Register `fn` for the given `kinds` (None = every kind). Returns
        an unsubscribe callable. Unknown kind names are rejected loudly —
        a typo'd kind would otherwise subscribe to silence forever."""
        keys: Tuple[Optional[str], ...]
        if kinds is None:
            keys = (None,)
        else:
            for k in kinds:
                if k not in EVENT_KINDS:
                    raise ValueError(
                        f"unknown event kind {k!r}; valid kinds: "
                        f"{', '.join(EVENT_KINDS)}")
            keys = tuple(kinds)
        for k in keys:
            self._subs.setdefault(k, []).append(fn)

        def unsubscribe():
            for k in keys:
                subs = self._subs.get(k)
                if subs and fn in subs:
                    subs.remove(fn)

        return unsubscribe

    def wants(self, kind: str) -> bool:
        return bool(self._subs.get(None) or self._subs.get(kind))

    def publish(self, ev: ServeEvent):
        self.n_published += 1
        for fn in self._subs.get(ev.kind, ()):
            fn(ev)
        for fn in self._subs.get(None, ()):
            fn(ev)
