"""Conversation-level metrics (§1, §5.1).

* TTFET — time-to-first-effective-token: arrival -> first token of the
  conversation's FINAL, user-visible reply turn. Intermediate turns emit
  tool calls the user never reads; TTFET is a property of the conversation.
* Last-turn TBT — mean time-between-tokens within the final turn.
* E2E — arrival -> last token of the final turn.
Conventional per-turn TTFT / TBT distributions are also recorded for
comparison with prior work (they conflate tool-call turns with the reply).
SLO threshold: 5× the interference-free single-request baseline per metric
(standard practice; §5.3).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class TurnRecord:
    turn_idx: int
    arrival_s: float = 0.0      # turn became runnable (tool returned)
    first_token_s: float = 0.0  # TTFT reference point
    last_token_s: float = 0.0
    n_output_tokens: int = 0
    token_times: Optional[List[float]] = None  # optional full trace

    @property
    def ttft_s(self) -> float:
        return self.first_token_s - self.arrival_s

    @property
    def tbt_s(self) -> float:
        if self.n_output_tokens <= 1:
            return 0.0
        return (self.last_token_s - self.first_token_s) / (self.n_output_tokens - 1)


@dataclasses.dataclass
class ConversationRecord:
    cid: int
    arrival_s: float
    turns: List[TurnRecord] = dataclasses.field(default_factory=list)
    n_kv_transfers: int = 0
    n_remote_turns: int = 0
    recovered: bool = False  # re-prefilled after a decoder failure
    # one entry per recovery: trigger (replica death / tool return to a dead
    # or evicted binding) -> decode of the interrupted turn resumed
    recovery_latency_s: List[float] = dataclasses.field(default_factory=list)
    n_tool_evictions: int = 0  # tool-deadline watchdog freed this slot

    @property
    def done(self) -> bool:
        return bool(self.turns)

    @property
    def ttfet_s(self) -> float:
        """First token of the final (user-visible) turn, from arrival."""
        return self.turns[-1].first_token_s - self.arrival_s

    @property
    def last_turn_tbt_s(self) -> float:
        return self.turns[-1].tbt_s

    @property
    def e2e_s(self) -> float:
        return self.turns[-1].last_token_s - self.arrival_s


def gmean(xs: Sequence[float]) -> float:
    xs = [max(x, 1e-9) for x in xs]
    if not xs:
        return float("nan")
    return float(math.exp(sum(math.log(x) for x in xs) / len(xs)))


def p95(xs: Sequence[float]) -> float:
    return float(np.percentile(xs, 95)) if len(xs) else float("nan")


@dataclasses.dataclass
class SLOThresholds:
    """5× the single-request, interference-free baseline per metric."""
    ttfet_s: float
    last_tbt_s: float
    e2e_s: float
    multiplier: float = 5.0

    def violations(self, recs: Sequence[ConversationRecord]) -> Dict[str, float]:
        n = max(len(recs), 1)
        v_ttfet = sum(r.ttfet_s > self.multiplier * self.ttfet_s for r in recs)
        v_tbt = sum(r.last_turn_tbt_s > self.multiplier * self.last_tbt_s
                    for r in recs)
        v_e2e = sum(r.e2e_s > self.multiplier * self.e2e_s for r in recs)
        return {"ttfet": v_ttfet / n, "last_tbt": v_tbt / n, "e2e": v_e2e / n}


def summarize(recs: Sequence[ConversationRecord],
              slo: Optional[SLOThresholds] = None,
              energy_joules: Optional[float] = None,
              total_tokens: Optional[int] = None) -> Dict[str, float]:
    """total_tokens: tokens processed (input+output) for tokens/joule; falls
    back to generated output tokens when not provided."""
    recs = [r for r in recs if r.done]
    ttfet = [r.ttfet_s for r in recs]
    tbt = [r.last_turn_tbt_s for r in recs if r.last_turn_tbt_s > 0]
    e2e = [r.e2e_s for r in recs]
    out = {
        "n_conversations": len(recs),
        "ttfet_gmean": gmean(ttfet), "ttfet_p95": p95(ttfet),
        "last_tbt_gmean": gmean(tbt), "last_tbt_p95": p95(tbt),
        "e2e_gmean": gmean(e2e), "e2e_p95": p95(e2e),
        "kv_transfers_per_conv": float(np.mean(
            [r.n_kv_transfers for r in recs])) if recs else 0.0,
        "remote_turns_per_conv": float(np.mean(
            [r.n_remote_turns for r in recs])) if recs else 0.0,
    }
    # failure-recovery view: how many conversations replayed, and how long
    # each recovery took (trigger -> interrupted turn's decode resumed).
    # Keys are always present (stable benchmark schemas); zeros when the
    # run was failure-free.
    rec_lat = [l for r in recs for l in r.recovery_latency_s]
    out.update({
        "n_recovered": int(sum(r.recovered for r in recs)),
        "n_tool_evictions": int(sum(r.n_tool_evictions for r in recs)),
        "recovery_latency_mean_s": float(np.mean(rec_lat)) if rec_lat else 0.0,
        "recovery_latency_p95_s": p95(rec_lat) if rec_lat else 0.0,
    })
    if slo is not None:
        out.update({f"slo_viol_{k}": v
                    for k, v in slo.violations(recs).items()})
    if energy_joules is not None and energy_joules > 0:
        if total_tokens is None:
            total_tokens = sum(t.n_output_tokens for r in recs for t in r.turns)
        out["tokens_per_joule"] = total_tokens / energy_joules
        out["energy_joules"] = energy_joules
    return out


def per_conversation_slo_violations(
        loaded: Sequence[ConversationRecord],
        baseline: Dict[int, ConversationRecord],
        multiplier: float = 5.0) -> Dict[str, float]:
    """SLO per §5.3 at conversation granularity: each conversation is judged
    against 5× ITS OWN interference-free execution (same turns, no batching
    or queueing) — the conversation-level analogue of the per-request
    baseline."""
    n = max(len(loaded), 1)
    v = {"ttfet": 0, "last_tbt": 0, "e2e": 0}
    for r in loaded:
        b = baseline[r.cid]
        v["ttfet"] += r.ttfet_s > multiplier * max(b.ttfet_s, 1e-6)
        v["last_tbt"] += r.last_turn_tbt_s > multiplier * max(
            b.last_turn_tbt_s, 1e-4)
        v["e2e"] += r.e2e_s > multiplier * max(b.e2e_s, 1e-6)
    return {k: c / n for k, c in v.items()}


def per_turn_distributions(recs: Sequence[ConversationRecord]
                           ) -> Dict[str, np.ndarray]:
    """Conventional per-turn TTFT/TBT pools across all turns (Fig. 11)."""
    ttft = np.array([t.ttft_s for r in recs for t in r.turns])
    tbt = np.array([t.tbt_s for r in recs for t in r.turns
                    if t.n_output_tokens > 1])
    return {"ttft": np.sort(ttft), "tbt": np.sort(tbt)}
