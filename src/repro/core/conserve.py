"""ConServe: conversation-level disaggregated scheduling (§4).

The whole policy, verbatim from the paper:
  1. Turn-1 prefill routes to the prefiller (least-backlogged when several).
  2. On prefill completion, bind to the decoder with the lowest *active* KV
     occupancy; transfer the KV cache exactly once.
  3. Every later turn executes on the bound decoder. No re-evaluation, no
     migration, no learned cost model, no decode-side prediction — ever.

Both signals read are direct observations (input-token counts; per-decoder
KV occupancy). Straggler avoidance is also observational: decoders whose
measured TBT drifts beyond k× the pool median stop receiving NEW bindings
(already-placed conversations stay put — ConServe never migrates).
"""
from __future__ import annotations

from .conversation import ConversationView, TurnView
from .scheduler import Placement, Scheduler, register
from .signals import ClusterView


@register
class ConServeScheduler(Scheduler):
    name = "conserve"

    def __init__(self, straggler_factor: float = 0.0):
        # 0.0 disables straggler screening (paper's minimal policy);
        # fault-tolerant deployments set e.g. 3.0.
        self.straggler_factor = straggler_factor
        self._bindings = {}

    def place_first_prefill(self, conv: ConversationView,
                            view: ClusterView) -> Placement:
        return Placement(self.least_loaded_prefiller(view))

    def bind_decoder(self, conv: ConversationView,
                     view: ClusterView) -> Placement:
        nid = self.min_kv_decoder(view, self.straggler_factor)
        self._bindings[conv.cid] = nid
        # the one and only KV movement this conversation will ever make
        return Placement(nid, kv_transfer=True)

    def place_turn(self, turn: TurnView, bound_decoder: int,
                   view: ClusterView) -> Placement:
        # Pinned for the conversation's lifetime: local append-prefill with
        # full prefix-cache reuse, zero transfer.
        return Placement(bound_decoder, kv_transfer=False)

    def on_conversation_end(self, cid: int, view: ClusterView) -> None:
        self._bindings.pop(cid, None)


@register
class ConServeRebalanceScheduler(ConServeScheduler):
    """ConServe + occupancy-aware admission re-offer (ROADMAP open item).

    The base policy is unchanged — one-shot binding to the min-KV decoder,
    pinned tail — but a one-shot KV binding PARKED on a saturated decoder is
    re-offered to the eligible decoder with the most observed KV headroom
    (`kv_headroom_tokens`, with a free slot) instead of waiting FIFO behind
    that decoder's own releases. Both inputs are observables the runtime
    already maintains; nothing is predicted. Only decode-role queues are
    touched: a parked admission on a prefill/mixed node is an arrival, not a
    binding, and stays where the placement decision put it."""
    name = "conserve_rebalance"

    def reoffer_admission(self, cid: int, node_id: int,
                          view: ClusterView):
        if view.node(node_id).role != "decode":
            return None
        eligible = [d for d in view.nodes("decode") if d.free_slots > 0]
        if not eligible:
            return None
        best = max(eligible,
                   key=lambda d: (d.kv_headroom_tokens, -d.node_id))
        here = view.node(node_id)
        if best.node_id != node_id and (
                here.free_slots <= 0
                or best.kv_headroom_tokens > here.kv_headroom_tokens):
            return Placement(best.node_id, kv_transfer=True)
        return None


@register
class ConServeSJFRefillScheduler(ConServeScheduler):
    """ConServe + shortest-context-first admission refill (ROADMAP open
    item: a non-trivial `select_refill`).

    The base policy is unchanged — placement, binding and the pinned tail
    are verbatim ConServe — but whenever a node re-offers its admission
    queue (every release point and every decode-rotation chunk cut) the
    parked conversations are tried SHORTEST OBSERVED CONTEXT first instead
    of FIFO. A short-context admission holds its slot for the least KV and
    tends to release it soonest, so draining the queue smallest-first
    maximizes slot turnover under saturation (classic SJF, applied to slot
    residency).

    Observation-only: the context a conversation would land with is
    exactly what the scheduler already SAW at its own decision points —
    `first_input_len` at arrival, `context_tokens + append_tokens` at each
    turn arrival — accumulated the same way ConServe accumulates
    `_bindings`. Nothing decode-side is predicted; a cid this scheduler
    never saw (nothing arrives that way in practice) keeps its FIFO rank.
    Refill order changes WHEN parked work runs, never WHAT it computes:
    per-(cid, turn) token streams are refill-order-invariant by the
    runtime contract, and the unit tests assert both the reorder and the
    invariance."""
    name = "conserve_sjf_refill"

    def __init__(self, straggler_factor: float = 0.0):
        super().__init__(straggler_factor)
        self._seen_ctx = {}  # cid -> last context observed at a decision

    def place_first_prefill(self, conv: ConversationView,
                            view: ClusterView) -> Placement:
        self._seen_ctx[conv.cid] = conv.first_input_len
        return super().place_first_prefill(conv, view)

    def place_turn(self, turn: TurnView, bound_decoder: int,
                   view: ClusterView) -> Placement:
        self._seen_ctx[turn.cid] = turn.context_tokens + turn.append_tokens
        return super().place_turn(turn, bound_decoder, view)

    def on_conversation_end(self, cid: int, view: ClusterView) -> None:
        self._seen_ctx.pop(cid, None)
        super().on_conversation_end(cid, view)

    def select_refill(self, node_id: int, waiting, view: ClusterView):
        fifo_rank = {cid: i for i, cid in enumerate(waiting)}
        return sorted(waiting, key=lambda cid: (
            self._seen_ctx.get(cid, float("inf")), fifo_rank[cid]))
