"""ConServe: conversation-level disaggregated scheduling (§4).

The whole policy, verbatim from the paper:
  1. Turn-1 prefill routes to the prefiller (least-backlogged when several).
  2. On prefill completion, bind to the decoder with the lowest *active* KV
     occupancy; transfer the KV cache exactly once.
  3. Every later turn executes on the bound decoder. No re-evaluation, no
     migration, no learned cost model, no decode-side prediction — ever.

Both signals read are direct observations (input-token counts; per-decoder
KV occupancy). Straggler avoidance is also observational: decoders whose
measured TBT drifts beyond k× the pool median stop receiving NEW bindings
(already-placed conversations stay put — ConServe never migrates).
"""
from __future__ import annotations

from .conversation import ConversationView, TurnView
from .scheduler import Placement, Scheduler, register
from .signals import ClusterView


@register
class ConServeScheduler(Scheduler):
    name = "conserve"

    def __init__(self, straggler_factor: float = 0.0):
        # 0.0 disables straggler screening (paper's minimal policy);
        # fault-tolerant deployments set e.g. 3.0.
        self.straggler_factor = straggler_factor
        self._bindings = {}

    def place_first_prefill(self, conv: ConversationView,
                            view: ClusterView) -> Placement:
        return Placement(self.least_loaded_prefiller(view))

    def bind_decoder(self, conv: ConversationView,
                     view: ClusterView) -> Placement:
        nid = self.min_kv_decoder(view, self.straggler_factor)
        self._bindings[conv.cid] = nid
        # the one and only KV movement this conversation will ever make
        return Placement(nid, kv_transfer=True)

    def place_turn(self, turn: TurnView, bound_decoder: int,
                   view: ClusterView) -> Placement:
        # Pinned for the conversation's lifetime: local append-prefill with
        # full prefix-cache reuse, zero transfer.
        return Placement(bound_decoder, kv_transfer=False)

    def on_conversation_end(self, cid: int, view: ClusterView) -> None:
        self._bindings.pop(cid, None)
