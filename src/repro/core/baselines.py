"""Baselines (§5.1): Collocated, Full Disaggregation, and AMPD (per-turn
prediction-based disaggregation with an injectable wrong-prediction rate —
the paper's structural-brittleness probe, Fig. 12)."""
from __future__ import annotations

import numpy as np

from .conversation import ConversationView, TurnView
from .scheduler import Placement, Scheduler, register
from .signals import ClusterView


@register
class CollocatedScheduler(Scheduler):
    """All replicas are mixed-batch; a conversation lives entirely on one
    replica chosen at arrival (least KV); prefill and decode batch together
    (chunked prefill bounds the per-step stall; interference modeled by the
    runtime per Fig. 5)."""
    name = "collocated"

    def place_first_prefill(self, conv: ConversationView,
                            view: ClusterView) -> Placement:
        nodes = view.nodes("mixed")
        nid = min(nodes, key=lambda n: (n.active_kv_tokens,
                                        n.queued_prefill_tokens)).node_id
        return Placement(nid)

    def bind_decoder(self, conv, view) -> Placement:
        # already on the mixed replica; no transfer
        raise RuntimeError("collocated runtime binds at arrival")

    def place_turn(self, turn: TurnView, bound_decoder: int,
                   view: ClusterView) -> Placement:
        return Placement(bound_decoder, kv_transfer=False)


@register
class FullDisaggScheduler(Scheduler):
    """Every turn's prefill routes through the prefill node (classic PD
    disaggregation applied per-request): pays a KV transfer on every turn and
    forfeits cross-turn prefix reuse on the decoder."""
    name = "full_disagg"

    def place_first_prefill(self, conv: ConversationView,
                            view: ClusterView) -> Placement:
        return Placement(self.least_loaded_prefiller(view))

    def bind_decoder(self, conv: ConversationView,
                     view: ClusterView) -> Placement:
        return Placement(self.min_kv_decoder(view), kv_transfer=True)

    def place_turn(self, turn: TurnView, bound_decoder: int,
                   view: ClusterView) -> Placement:
        # remote append-prefill on the prefiller; KV moves decoder -> prefiller
        # -> decoder (bidirectional, runtime charges both directions)
        return Placement(self.least_loaded_prefiller(view), kv_transfer=True)


@register
class AMPDScheduler(Scheduler):
    """Per-turn prediction-based disaggregation (He et al., 2026), at our
    best effort per §5.1: for every turn-2+ prefill an offline cost model
    picks local-on-decoder vs remote-on-prefiller. In the agentic regime the
    correct answer is always 'local' (appends are uniformly short and carry a
    hot prefix cache), so the per-turn decision collapses to a fixed local
    policy — *except* when the predictor errs. `wrong_prediction_rate`
    injects that error: with probability p the turn migrates to the
    prefiller, paying a bidirectional KV move and adding unanticipated load
    to the saturation-provisioned prefiller (Fig. 12's x-axis)."""
    name = "ampd"

    def __init__(self, wrong_prediction_rate: float = 0.10, seed: int = 0):
        self.p = float(wrong_prediction_rate)
        self.rng = np.random.RandomState(seed)

    def place_first_prefill(self, conv: ConversationView,
                            view: ClusterView) -> Placement:
        return Placement(self.least_loaded_prefiller(view))

    def bind_decoder(self, conv: ConversationView,
                     view: ClusterView) -> Placement:
        return Placement(self.min_kv_decoder(view), kv_transfer=True)

    def _cost_model_says_remote(self, turn: TurnView,
                                view: ClusterView) -> bool:
        """The offline cost model (profiled prefill curve vs an interference
        estimate that, per §5.4, omits decoder KV pressure and prefiller
        queueing). In our traces appends are short, so it returns local;
        its failure mode is modeled by the injected error rate."""
        remote_cost = view.prefill_curve.latency_s(turn.append_tokens)
        local_cost = view.prefill_curve.latency_s(turn.append_tokens) * 0.1
        return remote_cost < local_cost  # never true for short appends

    def place_turn(self, turn: TurnView, bound_decoder: int,
                   view: ClusterView) -> Placement:
        remote = self._cost_model_says_remote(turn, view)
        if self.rng.random_sample() < self.p:
            remote = not remote  # mispredicted turn
        if remote:
            return Placement(self.least_loaded_prefiller(view),
                             kv_transfer=True)
        return Placement(bound_decoder, kv_transfer=False)
