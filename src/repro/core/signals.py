"""The two observable signals ConServe schedules on (§4.2), plus the
restricted cluster view handed to schedulers.

1. The prefill latency curve — profiled OFFLINE as a deterministic function
   of input-token count (quadratic once attention dominates, §3.1). Given an
   incoming conversation's first-turn prompt length the scheduler reads off
   expected prefiller utilization in O(1).
2. Per-decoder *active* KV-cache occupancy — decremented at conversation
   termination so it reflects only live state. For recurrent-state families
   (RWKV6 / RG-LRU) per-token growth is ~0 and the signal degenerates to the
   active-slot count (DESIGN.md §4); both are exposed.

Neither is a forecast; both are properties of state the system already
maintains.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# ----- replica lifecycle states ----------------------------------------------
# ACTIVE      — takes placements and refills (the only schedulable state).
# QUARANTINED — observed straggler: its observed_tbt_ema_s exceeded k× the
#               fleet median over a window of observed chunks. Takes no new
#               placements or refills; parked admissions drain to peers;
#               in-flight tails keep running so observations keep flowing.
# DRAINING    — the quarantined node's observed EMA recovered but in-flight
#               tails remain; it re-activates when the last tail leaves.
# A dead node (alive=False) has no lifecycle of its own: revival resets it
# to ACTIVE. All transitions condition on observed state only.
NODE_ACTIVE = "ACTIVE"
NODE_QUARANTINED = "QUARANTINED"
NODE_DRAINING = "DRAINING"


@dataclasses.dataclass
class PrefillLatencyCurve:
    """TTFT(L) = a·L² + b·L + c  (seconds). Fit from offline profiling; the
    quadratic term captures attention, the linear term the projections."""
    a: float
    b: float
    c: float

    def latency_s(self, n_tokens: int) -> float:
        L = float(n_tokens)
        return self.a * L * L + self.b * L + self.c

    @staticmethod
    def fit(lengths: Sequence[int], latencies: Sequence[float]
            ) -> Tuple["PrefillLatencyCurve", float]:
        """Least-squares quadratic fit; returns (curve, R^2)."""
        x = np.asarray(lengths, dtype=np.float64)
        y = np.asarray(latencies, dtype=np.float64)
        A = np.stack([x * x, x, np.ones_like(x)], axis=1)
        coef, *_ = np.linalg.lstsq(A, y, rcond=None)
        pred = A @ coef
        ss_res = float(((y - pred) ** 2).sum())
        ss_tot = float(((y - y.mean()) ** 2).sum()) or 1e-12
        return PrefillLatencyCurve(*coef), 1.0 - ss_res / ss_tot


@dataclasses.dataclass
class NodeState:
    """Observable per-node state the runtime maintains and schedulers read."""
    node_id: int
    role: str  # "prefill" | "decode" | "mixed"
    # prefill side
    queued_prefill_tokens: int = 0
    # decode side
    active_kv_tokens: int = 0
    active_conversations: int = 0
    kv_capacity_tokens: int = 300_000
    slot_capacity: int = 64
    # admission / backpressure observables (repro.core.runtime): work parked
    # in this node's admission queue, KV slots currently held, and KV tokens
    # reserved by admitted-but-not-yet-resident work. All three are counters
    # the runtime already maintains — observations, never predictions.
    queued_conversations: int = 0
    used_slots: int = 0
    reserved_kv_tokens: int = 0
    # decode-rotation observables: how well the decode iterations keep their
    # batch lanes busy. `decode_scan_steps` counts scan steps the node ran
    # (every lane computes in lockstep per step), `decode_lane_steps_emitting`
    # counts lane-steps that belonged to an EMITTING slot (live + the masked
    # no-op tail a slot spends frozen after finishing mid-chunk), and
    # `decode_lane_steps_live` counts lane-steps that emitted a real token.
    # All three are counters of work the runtime already dispatched —
    # observations, never predictions; both backends maintain them.
    decode_scan_steps: int = 0
    decode_lane_steps_emitting: int = 0
    decode_lane_steps_live: int = 0
    # health (observation-based straggler signal)
    observed_tbt_ema_s: float = 0.0
    alive: bool = True
    # lifecycle (see module constants): only alive+ACTIVE nodes are visible
    # through ClusterView.nodes(), so schedulers never place on a straggler
    lifecycle: str = NODE_ACTIVE
    # failure-recovery observable: prefill tokens this node computed to
    # REBUILD journaled context after a replica death or tool-deadline
    # eviction — replay work is charged here, never to the victim
    # conversation's TTFET history (Maestro-style honest recovery cost)
    replayed_prefill_tokens: int = 0
    # prefix-KV-pool observables: immutable shared-prefix rows this node
    # holds outside any slot. Counters of pool state/events the runtime
    # already owns (tokens resident, entries, observed reuse hits, evictions)
    # — observations a scheduler may condition prefix-affinity placement on,
    # never predictions of future reuse. Pool capacity is a SEPARATE budget
    # from kv_capacity_tokens: pooled rows never eat slot headroom, so
    # kv_headroom_tokens stays truthful about slot-landable work.
    pooled_prefix_tokens: int = 0
    pooled_prefix_entries: int = 0
    pooled_prefix_hits: int = 0
    pooled_prefix_evictions: int = 0

    @property
    def kv_utilization(self) -> float:
        return self.active_kv_tokens / max(self.kv_capacity_tokens, 1)

    @property
    def free_slots(self) -> int:
        return self.slot_capacity - self.used_slots

    @property
    def kv_headroom_tokens(self) -> int:
        """KV tokens this node can still take on: capacity minus live KV
        minus reservations of admitted-in-flight work."""
        return (self.kv_capacity_tokens - self.active_kv_tokens
                - self.reserved_kv_tokens)

    @property
    def masked_forward_fraction(self) -> float:
        """Fraction of this node's dispatched decode forwards that were
        masked no-ops: lane-steps spent on an emitting slot AFTER its
        per-slot share was exhausted (a slot finishing at step 3 of a
        32-step scan contributes 29 here). The quantity decode rotation
        exists to reclaim; 0.0 when the node never decoded."""
        if self.decode_lane_steps_emitting <= 0:
            return 0.0
        return 1.0 - (self.decode_lane_steps_live
                      / self.decode_lane_steps_emitting)

    @property
    def slot_busy_fraction(self) -> float:
        """Mean fraction of this node's KV slots that emitted a real token
        per executed scan step — lane occupancy including empty lanes, the
        saturation view of the same counters. 0.0 when the node never
        decoded."""
        denom = self.decode_scan_steps * max(self.slot_capacity, 1)
        return self.decode_lane_steps_live / denom if denom > 0 else 0.0


class ClusterView:
    """Read-only window onto observable cluster state. This is the ONLY
    interface scheduler policies receive — placement decisions can condition
    on nothing else (the paper's 'observation, not prediction' contract)."""

    def __init__(self, nodes: Dict[int, NodeState],
                 prefill_curve: PrefillLatencyCurve):
        self._nodes = nodes
        self.prefill_curve = prefill_curve

    def nodes(self, role: Optional[str] = None) -> List[NodeState]:
        out = [n for n in self._nodes.values()
               if n.alive and n.lifecycle == NODE_ACTIVE]
        if role:
            out = [n for n in out if n.role == role]
        return out

    def node(self, node_id: int) -> NodeState:
        return self._nodes[node_id]

    def prefill_backlog_s(self, node_id: int) -> float:
        """Expected time to drain a prefiller's queued input tokens — derived
        from the offline curve, not from any prediction of decode behavior."""
        n = self._nodes[node_id]
        return self.prefill_curve.latency_s(max(n.queued_prefill_tokens, 0))

    def median_decoder_tbt(self) -> float:
        ds = [n.observed_tbt_ema_s for n in self.nodes("decode")
              if n.observed_tbt_ema_s > 0]
        return float(np.median(ds)) if ds else 0.0
