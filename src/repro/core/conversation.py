"""Conversation / turn data model.

A conversation is the paper's scheduling unit: a stateful multi-turn program
— one heavy first-turn prefill followed by a memory-bound tail of
(append-prefill, decode, tool-call) turns. Trace fields that are
*unobservable at scheduling time* (output lengths, future turns, tool
latencies) are kept here for the replay runtime only; schedulers receive a
restricted `ConversationView` so policy code physically cannot peek.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass
class Turn:
    """One ReAct turn: tokens appended to the context (turn 1: the task
    prompt; turn 2+: the tool result), tokens the model will decode, and the
    tool latency that follows (0 for the final turn)."""
    append_tokens: int
    output_tokens: int
    tool_time_s: float = 0.0


@dataclasses.dataclass
class Conversation:
    cid: int
    arrival_s: float
    turns: List[Turn]
    # Shared-preamble identity (agentic fleets: many conversations open with
    # the same system-prompt / tool-schema prefix). `preamble_tokens` is the
    # length of that shared prefix INSIDE turn 0's append_tokens; two
    # conversations with the same (preamble_id, preamble_tokens) have
    # byte-identical first `preamble_tokens` input tokens. None/0 = no shared
    # prefix. The preamble is part of the context either way — it only tells
    # the runtime where turn 1 may split against a prefix KV pool.
    preamble_id: Optional[int] = None
    preamble_tokens: int = 0

    def __post_init__(self):
        if self.preamble_tokens and not (
                0 < self.preamble_tokens < self.turns[0].append_tokens):
            raise ValueError(
                f"conversation {self.cid}: preamble_tokens "
                f"({self.preamble_tokens}) must leave a non-empty turn-1 "
                f"delta inside first_input_len "
                f"({self.turns[0].append_tokens})")

    @property
    def n_turns(self) -> int:
        return len(self.turns)

    @property
    def first_input_len(self) -> int:
        return self.turns[0].append_tokens

    @property
    def total_input_tokens(self) -> int:
        return sum(t.append_tokens for t in self.turns)

    @property
    def total_output_tokens(self) -> int:
        return sum(t.output_tokens for t in self.turns)

    @property
    def decoder_token_volume(self) -> int:
        """L_d of §4.1: tokens handled by the decoder over the conversation's
        lifetime — turn-1 decode plus all turn-2+ prefill and decode."""
        return (self.total_output_tokens
                + sum(t.append_tokens for t in self.turns[1:]))

    def peak_context_tokens(self) -> int:
        return self.total_input_tokens + self.total_output_tokens


@dataclasses.dataclass(frozen=True)
class ConversationView:
    """What a scheduler is allowed to see when it must act: identity, arrival
    time, and the *first-turn input length* — nothing decode-side. The
    preamble identity is observable at arrival (the prompt bytes are in
    hand), so prefix-affinity placement stays within the observation rule."""
    cid: int
    arrival_s: float
    first_input_len: int
    preamble_id: Optional[int] = None
    preamble_tokens: int = 0


@dataclasses.dataclass(frozen=True)
class TurnView:
    """Observable turn-arrival info: the append length is in hand (the tool
    result has materialized); the turn's output length is not."""
    cid: int
    turn_idx: int
    append_tokens: int
    context_tokens: int  # accumulated KV length before this turn


def view_of(conv: Conversation) -> ConversationView:
    return ConversationView(conv.cid, conv.arrival_s, conv.first_input_len,
                            conv.preamble_id, conv.preamble_tokens)
