"""Synthetic agentic traces matching the paper's workload characterization
(§3, Fig. 1 — SWE-bench_bm25_13K replayed through swe-agent):

  * turn-1 input: tens of thousands of tokens (task + repository context),
    concentrated around the 13k retrieval budget;
  * turn-2+ appends: task-relevant tool output only, O(10^2) tokens;
  * outputs: high-variance, heavy-tailed (unpredictable at scheduling time);
  * turn counts: geometric-ish with a long tail;
  * tool latencies between turns (the conversation leaves compute but its KV
    stays pinned).

Calibrated so mean first input ≈ 15k and mean per-conversation decoder
volume ≈ 1k tokens, reproducing §5.1's provisioning sanity check.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import numpy as np

from repro.core.conversation import Conversation, Turn
from repro.core.provisioning import WorkloadStats


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    seed: int = 0
    # turn-1 prompt: lognormal centered near the 13k retrieval budget
    # (median 14k, sigma .35 -> mean ≈ 15k = §5.1's L_in, so the prefiller
    # saturation rate R* = 25k/15k ≈ 1.67 conv/s matches the paper's axis)
    first_input_median: float = 14_000.0
    first_input_sigma: float = 0.35
    first_input_max: int = 32_000
    # turn 2+ appends: hundreds of tokens
    append_median: float = 220.0
    append_sigma: float = 0.8
    append_max: int = 4_000
    # outputs: heavy-tailed, unpredictable
    output_median: float = 60.0
    output_sigma: float = 1.1
    output_max: int = 2_000
    # turns per conversation
    mean_turns: float = 9.0
    max_turns: int = 40
    # tool latency between turns
    tool_mean_s: float = 1.5
    # Shared preamble (agentic fleets launch many conversations from the
    # same system-prompt / tool-schema prefix). preamble_tokens > 0 gives a
    # `preamble_share` fraction of conversations a shared prefix of that
    # length inside turn 1, drawn uniformly from `n_preambles` distinct
    # identities. The preamble EXTENDS turn 1 (sampled task prompt stays
    # intact) so the non-preamble token distribution is unchanged.
    preamble_tokens: int = 0
    n_preambles: int = 1
    preamble_share: float = 1.0


def _lognormal(rng, median, sigma, cap) -> int:
    v = rng.lognormal(np.log(median), sigma)
    return int(np.clip(v, 1, cap))


def generate_conversation(cfg: TraceConfig, rng: np.random.RandomState,
                          cid: int, arrival_s: float) -> Conversation:
    n_turns = int(np.clip(rng.geometric(1.0 / cfg.mean_turns), 1,
                          cfg.max_turns))
    turns: List[Turn] = []
    for i in range(n_turns):
        append = (_lognormal(rng, cfg.first_input_median,
                             cfg.first_input_sigma, cfg.first_input_max)
                  if i == 0 else
                  _lognormal(rng, cfg.append_median, cfg.append_sigma,
                             cfg.append_max))
        out = _lognormal(rng, cfg.output_median, cfg.output_sigma,
                         cfg.output_max)
        tool = float(rng.exponential(cfg.tool_mean_s)) if i < n_turns - 1 else 0.0
        turns.append(Turn(append_tokens=append, output_tokens=out,
                          tool_time_s=tool))
    pid: Optional[int] = None
    ptok = 0
    if cfg.preamble_tokens > 0 and rng.uniform() < cfg.preamble_share:
        pid = int(rng.randint(cfg.n_preambles))
        ptok = int(cfg.preamble_tokens)
        t0 = turns[0]
        turns[0] = Turn(append_tokens=t0.append_tokens + ptok,
                        output_tokens=t0.output_tokens,
                        tool_time_s=t0.tool_time_s)
    return Conversation(cid=cid, arrival_s=arrival_s, turns=turns,
                        preamble_id=pid, preamble_tokens=ptok)


def generate_trace(n_conversations: int, rate_conv_per_s: float,
                   cfg: Optional[TraceConfig] = None,
                   arrival_process: str = "poisson",
                   pace_tokens_per_s: float = 25_000.0) -> List[Conversation]:
    """arrival_process:
      'poisson'    — Poisson arrivals at rate_conv_per_s;
      'saturation' — deterministic 1/rate inter-arrivals;
      'paced'      — the paper's 1.634 conv/s synthesized pattern: each
        inter-arrival equals the previous conversation's turn-1 prefill
        service time (first_input / T_p), holding the prefiller EXACTLY at
        its saturation throughput without exceeding it (§5.1, §5.3)."""
    cfg = cfg or TraceConfig()
    rng = np.random.RandomState(cfg.seed)
    t = 0.0
    convs = []
    for cid in range(n_conversations):
        c = generate_conversation(cfg, rng, cid, t)
        convs.append(c)
        if arrival_process == "poisson":
            t += float(rng.exponential(1.0 / rate_conv_per_s))
        elif arrival_process == "paced":
            t += c.first_input_len / pace_tokens_per_s
        else:
            t += 1.0 / rate_conv_per_s
    return convs


# ----- named scenario library ------------------------------------------------
# Seeded generators for the agentic patterns the paper's serving claims are
# exercised against. Each returns a plain `Conversation` list (the runtimes'
# only input), fully determined by (name, n_conversations, seed, scale):
# the same call is byte-identical across processes, which is what lets the
# gateway's live-streamed output be compared against an offline replay.
#
# `scale` picks the token regime: "paper" = the §3 characterization above
# (13k-ish first inputs); "engine" = the reduced-model regime the real-JAX
# backend serves in tests/CI (peak context bounded under the replicas'
# max_ctx=1024).

_ENGINE_SCALE = dict(first_input_median=150.0, first_input_max=500,
                     append_median=24.0, append_max=64,
                     output_median=10.0, output_max=32,
                     mean_turns=3.0, max_turns=6, tool_mean_s=0.05)


def _scale_cfg(scale: str, seed: int, **overrides) -> TraceConfig:
    if scale not in ("paper", "engine"):
        raise ValueError(f"unknown scale {scale!r}; use 'paper' or 'engine'")
    base = dict(_ENGINE_SCALE) if scale == "engine" else {}
    base.update(overrides)
    return TraceConfig(seed=seed, **base)


def pareto_burst(n_conversations: int, seed: int = 0, scale: str = "paper",
                 alpha: float = 1.3,
                 mean_gap_s: Optional[float] = None) -> List[Conversation]:
    """Heavy-tailed arrivals: Pareto inter-arrival gaps (shape `alpha`,
    mean `mean_gap_s`) — long quiet stretches punctuated by bursts that
    pile conversations onto the admission queues, the regime where
    backpressure observables (not predictions) drive placement."""
    cfg = _scale_cfg(scale, seed)
    rng = np.random.RandomState(seed + 101)
    gap = mean_gap_s if mean_gap_s is not None else (
        0.2 if scale == "engine" else 0.6)
    t, convs = 0.0, []
    for cid in range(n_conversations):
        convs.append(generate_conversation(cfg, rng, cid, t))
        # Lomax sample has mean 1/(alpha-1); rescale to the target mean gap
        t += gap * (alpha - 1.0) * float(rng.pareto(alpha))
    return convs


def supervisor_worker_dag(n_conversations: int, seed: int = 0,
                          scale: str = "paper",
                          workers_per_supervisor: int = 3,
                          dispatch_latency_s: Optional[float] = None):
    """Supervisor→worker DAG: each supervisor conversation spawns child
    (worker) conversations whose arrivals GATE on a tool return of the
    parent — a child dispatched from turn g cannot arrive before the
    parent's cumulative tool time through turn g has elapsed (the
    generatively-known part of the gating; serving latency only pushes the
    real return later). Returns ``(convs, edges)`` where edges are
    ``(parent_cid, gate_turn_idx, child_cid)`` so tests can assert the
    invariant directly."""
    cfg = _scale_cfg(scale, seed)
    rng = np.random.RandomState(seed + 202)
    dispatch = dispatch_latency_s if dispatch_latency_s is not None else (
        0.01 if scale == "engine" else 0.25)
    sup_gap = 0.5 if scale == "engine" else 5.0
    convs: List[Conversation] = []
    edges = []
    cid, t = 0, 0.0
    while cid < n_conversations:
        sup = generate_conversation(cfg, rng, cid, t)
        convs.append(sup)
        sup_cid = cid
        cid += 1
        for j in range(min(workers_per_supervisor, n_conversations - cid)):
            gate = int(rng.randint(sup.n_turns))
            cum_tool = sum(tn.tool_time_s for tn in sup.turns[:gate + 1])
            child = generate_conversation(
                cfg, rng, cid, t + cum_tool + dispatch * (j + 1))
            convs.append(child)
            edges.append((sup_cid, gate, cid))
            cid += 1
        t += float(rng.exponential(sup_gap))
    return convs, edges


def supervisor_worker(n_conversations: int, seed: int = 0,
                      scale: str = "paper",
                      **kw) -> List[Conversation]:
    return supervisor_worker_dag(n_conversations, seed=seed, scale=scale,
                                 **kw)[0]


def hitl_longpark(n_conversations: int, seed: int = 0, scale: str = "paper",
                  park_share: float = 0.25,
                  park_s: Optional[float] = None) -> List[Conversation]:
    """Human-in-the-loop: a `park_share` fraction of conversations has one
    tool boundary stretched to a long wait (a person reviewing), so its KV
    sits pinned in TOOL_WAIT for orders of magnitude longer than a tool
    call — the pattern that makes conversation-level residency decisions
    matter."""
    cfg = _scale_cfg(scale, seed)
    rng = np.random.RandomState(seed + 303)
    park = park_s if park_s is not None else (
        1.0 if scale == "engine" else 120.0)
    gap = 0.3 if scale == "engine" else 1.0
    t, convs = 0.0, []
    for cid in range(n_conversations):
        c = generate_conversation(cfg, rng, cid, t)
        parked = rng.uniform() < park_share
        if parked and c.n_turns > 1:
            # pick a non-final turn; its tool call becomes the HITL wait
            i = int(rng.randint(c.n_turns - 1))
            c.turns[i] = Turn(append_tokens=c.turns[i].append_tokens,
                              output_tokens=c.turns[i].output_tokens,
                              tool_time_s=park * float(rng.uniform(0.5, 1.5)))
        convs.append(c)
        t += float(rng.exponential(gap))
    return convs


def shared_preamble_fleet(n_conversations: int, seed: int = 0,
                          scale: str = "paper", n_preambles: int = 3,
                          preamble_share: float = 0.8) -> List[Conversation]:
    """Agentic fleet launched from a handful of shared system-prompt /
    tool-schema preambles, arriving in tight bursts — the shape that
    exercises the prefix KV pool (turn-1 prefills past a pooled preamble
    compute only the delta)."""
    over = dict(preamble_tokens=2_000, n_preambles=n_preambles,
                preamble_share=preamble_share)
    if scale == "engine":
        # keep peak context under the test replicas' max_ctx=1024 even with
        # the preamble extending turn 1
        over.update(preamble_tokens=64, first_input_max=400)
    cfg = _scale_cfg(scale, seed, **over)
    rng = np.random.RandomState(seed + 404)
    burst, in_gap = 4, (0.002 if scale == "engine" else 0.01)
    gap = 0.5 if scale == "engine" else 4.0
    t, convs = 0.0, []
    for cid in range(n_conversations):
        if cid and cid % burst == 0:
            t += float(rng.exponential(gap))
        else:
            t += in_gap
        convs.append(generate_conversation(cfg, rng, cid, t))
    return convs


SCENARIOS = {
    "pareto_burst": pareto_burst,
    "supervisor_worker": supervisor_worker,
    "hitl_longpark": hitl_longpark,
    "shared_preamble_fleet": shared_preamble_fleet,
}


def make_scenario(name: str, n_conversations: int, seed: int = 0,
                  scale: str = "paper", cid_offset: int = 0,
                  arrival_offset_s: float = 0.0,
                  **kwargs) -> List[Conversation]:
    """Build a named scenario. `cid_offset` / `arrival_offset_s` shift the
    generated ids and arrival clock so multiple scenarios can be combined
    into one workload without colliding."""
    if name not in SCENARIOS:
        raise ValueError(f"unknown scenario {name!r}; available: "
                         f"{', '.join(sorted(SCENARIOS))}")
    convs = SCENARIOS[name](n_conversations, seed=seed, scale=scale,
                            **kwargs)
    for c in convs:
        c.cid += cid_offset
        c.arrival_s += arrival_offset_s
    return convs


def workload_stats(convs: List[Conversation]) -> WorkloadStats:
    """Measured stats for the provisioning equations (§4.1)."""
    first = float(np.mean([c.first_input_len for c in convs]))
    vol = float(np.mean([c.decoder_token_volume for c in convs]))
    peak = float(np.mean([c.peak_context_tokens() for c in convs]))
    # lifetime approximation: tool time + decode at 1k tok/s + prefill time
    life = float(np.mean([
        sum(t.tool_time_s for t in c.turns)
        + c.total_output_tokens / 1_000.0
        + c.first_input_len / 25_000.0
        for c in convs]))
    return WorkloadStats(mean_first_input=first, mean_decoder_volume=vol,
                         mean_lifetime_s=life, mean_peak_kv_tokens=peak)
