"""Synthetic agentic traces matching the paper's workload characterization
(§3, Fig. 1 — SWE-bench_bm25_13K replayed through swe-agent):

  * turn-1 input: tens of thousands of tokens (task + repository context),
    concentrated around the 13k retrieval budget;
  * turn-2+ appends: task-relevant tool output only, O(10^2) tokens;
  * outputs: high-variance, heavy-tailed (unpredictable at scheduling time);
  * turn counts: geometric-ish with a long tail;
  * tool latencies between turns (the conversation leaves compute but its KV
    stays pinned).

Calibrated so mean first input ≈ 15k and mean per-conversation decoder
volume ≈ 1k tokens, reproducing §5.1's provisioning sanity check.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import numpy as np

from repro.core.conversation import Conversation, Turn
from repro.core.provisioning import WorkloadStats


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    seed: int = 0
    # turn-1 prompt: lognormal centered near the 13k retrieval budget
    # (median 14k, sigma .35 -> mean ≈ 15k = §5.1's L_in, so the prefiller
    # saturation rate R* = 25k/15k ≈ 1.67 conv/s matches the paper's axis)
    first_input_median: float = 14_000.0
    first_input_sigma: float = 0.35
    first_input_max: int = 32_000
    # turn 2+ appends: hundreds of tokens
    append_median: float = 220.0
    append_sigma: float = 0.8
    append_max: int = 4_000
    # outputs: heavy-tailed, unpredictable
    output_median: float = 60.0
    output_sigma: float = 1.1
    output_max: int = 2_000
    # turns per conversation
    mean_turns: float = 9.0
    max_turns: int = 40
    # tool latency between turns
    tool_mean_s: float = 1.5
    # Shared preamble (agentic fleets launch many conversations from the
    # same system-prompt / tool-schema prefix). preamble_tokens > 0 gives a
    # `preamble_share` fraction of conversations a shared prefix of that
    # length inside turn 1, drawn uniformly from `n_preambles` distinct
    # identities. The preamble EXTENDS turn 1 (sampled task prompt stays
    # intact) so the non-preamble token distribution is unchanged.
    preamble_tokens: int = 0
    n_preambles: int = 1
    preamble_share: float = 1.0


def _lognormal(rng, median, sigma, cap) -> int:
    v = rng.lognormal(np.log(median), sigma)
    return int(np.clip(v, 1, cap))


def generate_conversation(cfg: TraceConfig, rng: np.random.RandomState,
                          cid: int, arrival_s: float) -> Conversation:
    n_turns = int(np.clip(rng.geometric(1.0 / cfg.mean_turns), 1,
                          cfg.max_turns))
    turns: List[Turn] = []
    for i in range(n_turns):
        append = (_lognormal(rng, cfg.first_input_median,
                             cfg.first_input_sigma, cfg.first_input_max)
                  if i == 0 else
                  _lognormal(rng, cfg.append_median, cfg.append_sigma,
                             cfg.append_max))
        out = _lognormal(rng, cfg.output_median, cfg.output_sigma,
                         cfg.output_max)
        tool = float(rng.exponential(cfg.tool_mean_s)) if i < n_turns - 1 else 0.0
        turns.append(Turn(append_tokens=append, output_tokens=out,
                          tool_time_s=tool))
    pid: Optional[int] = None
    ptok = 0
    if cfg.preamble_tokens > 0 and rng.uniform() < cfg.preamble_share:
        pid = int(rng.randint(cfg.n_preambles))
        ptok = int(cfg.preamble_tokens)
        t0 = turns[0]
        turns[0] = Turn(append_tokens=t0.append_tokens + ptok,
                        output_tokens=t0.output_tokens,
                        tool_time_s=t0.tool_time_s)
    return Conversation(cid=cid, arrival_s=arrival_s, turns=turns,
                        preamble_id=pid, preamble_tokens=ptok)


def generate_trace(n_conversations: int, rate_conv_per_s: float,
                   cfg: Optional[TraceConfig] = None,
                   arrival_process: str = "poisson",
                   pace_tokens_per_s: float = 25_000.0) -> List[Conversation]:
    """arrival_process:
      'poisson'    — Poisson arrivals at rate_conv_per_s;
      'saturation' — deterministic 1/rate inter-arrivals;
      'paced'      — the paper's 1.634 conv/s synthesized pattern: each
        inter-arrival equals the previous conversation's turn-1 prefill
        service time (first_input / T_p), holding the prefiller EXACTLY at
        its saturation throughput without exceeding it (§5.1, §5.3)."""
    cfg = cfg or TraceConfig()
    rng = np.random.RandomState(cfg.seed)
    t = 0.0
    convs = []
    for cid in range(n_conversations):
        c = generate_conversation(cfg, rng, cid, t)
        convs.append(c)
        if arrival_process == "poisson":
            t += float(rng.exponential(1.0 / rate_conv_per_s))
        elif arrival_process == "paced":
            t += c.first_input_len / pace_tokens_per_s
        else:
            t += 1.0 / rate_conv_per_s
    return convs


def workload_stats(convs: List[Conversation]) -> WorkloadStats:
    """Measured stats for the provisioning equations (§4.1)."""
    first = float(np.mean([c.first_input_len for c in convs]))
    vol = float(np.mean([c.decoder_token_volume for c in convs]))
    peak = float(np.mean([c.peak_context_tokens() for c in convs]))
    # lifetime approximation: tool time + decode at 1k tok/s + prefill time
    life = float(np.mean([
        sum(t.tool_time_s for t in c.turns)
        + c.total_output_tokens / 1_000.0
        + c.first_input_len / 25_000.0
        for c in convs]))
    return WorkloadStats(mean_first_input=first, mean_decoder_volume=vol,
                         mean_lifetime_s=life, mean_peak_kv_tokens=peak)
