from .agentic import (TraceConfig, generate_conversation, generate_trace,
                      workload_stats, SCENARIOS, make_scenario, pareto_burst,
                      supervisor_worker, supervisor_worker_dag, hitl_longpark,
                      shared_preamble_fleet)
