from .agentic import TraceConfig, generate_conversation, generate_trace, workload_stats
