"""Quickstart: serve a small model with ConServe on REAL JAX replicas.

Builds a 1-prefiller + 2-decoder deployment of a reduced Qwen3 config,
replays a small agentic trace through the EngineServer (real forward passes,
real KV transfers), and prints the conversation-level metrics the paper
introduces (TTFET, last-turn TBT, E2E).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax

from repro.configs import get_reduced
from repro.core import make_scheduler
from repro.core.metrics import summarize
from repro.engine import EngineServer, ReplicaEngine
from repro.models import build_model
from repro.traces import TraceConfig, generate_trace


def main():
    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"model: {cfg.name} (reduced: {model.n_params()/1e3:.0f}k params, "
          f"{cfg.n_layers}L d={cfg.d_model})")

    replicas = [
        ReplicaEngine(cfg, params, n_slots=16, max_ctx=1024, replica_id=0,
                      role="prefill"),
        ReplicaEngine(cfg, params, n_slots=16, max_ctx=1024, replica_id=1),
        ReplicaEngine(cfg, params, n_slots=16, max_ctx=1024, replica_id=2),
    ]
    server = EngineServer(make_scheduler("conserve"), replicas)

    tc = TraceConfig(first_input_median=150, first_input_sigma=0.4,
                     first_input_max=500, append_median=24, append_sigma=0.5,
                     append_max=64, output_median=10, output_sigma=0.6,
                     output_max=32, mean_turns=3.0, max_turns=6,
                     tool_mean_s=0.05)
    trace = generate_trace(12, 2.0, cfg=tc)
    print(f"trace: {len(trace)} conversations, "
          f"{sum(c.n_turns for c in trace)} turns")

    recs = server.serve(trace)
    s = summarize(recs)
    print("\n== conversation-level metrics (ConServe) ==")
    print(f"  TTFET      gmean {s['ttfet_gmean']:.3f}s   p95 {s['ttfet_p95']:.3f}s")
    print(f"  last TBT   gmean {s['last_tbt_gmean']*1e3:.1f}ms")
    print(f"  E2E        gmean {s['e2e_gmean']:.3f}s")
    print(f"  KV transfers/conversation: {s['kv_transfers_per_conv']:.2f} "
          f"(ConServe contract: exactly 1.0)")
    print(f"  remote turn-2+ prefills:   {s['remote_turns_per_conv']:.2f} "
          f"(pinned tail: 0.0)")
    tp = sum(r.n_prefill_tokens for r in replicas)
    td = sum(r.n_decode_tokens for r in replicas)
    print(f"  real tokens processed: {tp} prefill, {td} decode")


if __name__ == "__main__":
    main()
