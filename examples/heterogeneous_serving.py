"""Heterogeneous-tier serving (Fig. 13): map the compute-bound turn-1
prefill to the full-power tier and the memory-bound tail to power-capped
decoders; also demonstrates fault recovery and observation-driven
autoscaling in the same run.

    PYTHONPATH=src python examples/heterogeneous_serving.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.cluster import (A40, A40_CAPPED, Autoscaler, AutoscalerConfig,
                           NodeCostModel, ServedModelProfile, build_cluster,
                           paper_deployment)
from repro.core import make_scheduler
from repro.core.metrics import summarize
from repro.traces import TraceConfig, generate_trace


def main():
    trace = generate_trace(200, 1.634, TraceConfig(seed=19),
                           arrival_process="paced")
    total = sum(c.total_input_tokens + c.total_output_tokens for c in trace)

    print("== homogeneous (300W everywhere) vs heterogeneous (200W decoders) ==")
    res = {}
    for het in (False, True):
        sim = paper_deployment("conserve", heterogeneous=het)
        sim.submit(trace).run()
        res[het] = summarize(sim.results(), energy_joules=sim.total_energy_j(),
                             total_tokens=total)
        tag = "hetero" if het else "homog"
        print(f"  {tag:7s} tok/J={res[het]['tokens_per_joule']:7.1f}  "
              f"p95 TTFET={res[het]['ttfet_p95']:6.1f}s  "
              f"lastTBT={res[het]['last_tbt_gmean']*1e3:5.1f}ms")
    gain = res[True]["tokens_per_joule"] / res[False]["tokens_per_joule"] - 1
    print(f"  energy-efficiency gain: {gain:+.1%} at ~unchanged latency\n")

    print("== fault tolerance + elasticity on the heterogeneous pool ==")
    sched = make_scheduler("conserve", straggler_factor=3.0)
    sim = build_cluster(sched, n_prefill=1, n_decode=2,
                        prefill_tier=A40, decode_tier=A40_CAPPED)
    scaler = Autoscaler(sim, NodeCostModel(A40_CAPPED, ServedModelProfile()),
                        AutoscalerConfig(check_interval_s=10.0,
                                         kv_high_watermark=0.6,
                                         provision_delay_s=15.0)).start()
    sim.submit(trace)
    sim.inject_failure(node_id=1, at_s=40.0)  # kill a decoder mid-run
    sim.run()
    recs = sim.results()
    rec_n = sum(r.recovered for r in recs)
    print(f"  completed {len(recs)}/{len(trace)} conversations; "
          f"{rec_n} recovered by deterministic replay after the failure")
    for line in sim.log[:4]:
        print("   ", line)
    for t, kind, info in scaler.events[:4]:
        print(f"    t={t:.0f}s autoscaler: {kind} ({info})")


if __name__ == "__main__":
    main()
