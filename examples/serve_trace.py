"""Replay a paper-scale agentic trace through the calibrated cluster runtime
and compare all four systems (ConServe, AMPD, Collocated, Full Disagg) at the
saturation operating point — a compact reproduction of Fig. 10/12.

    PYTHONPATH=src python examples/serve_trace.py [--n 250] [--rate paced]
                                                  [--scenario NAME] [--seed S]

--scenario swaps the classic paced trace for a named workload from the
scenario library (pareto_burst, supervisor_worker, hitl_longpark,
shared_preamble_fleet) at paper scale; 'classic' (default) keeps the
original saturation-paced TraceConfig(seed=17) replay.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.cluster import paper_deployment
from repro.core.metrics import summarize
from repro.traces import TraceConfig, generate_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=250)
    ap.add_argument("--rate", default="paced",
                    help="'paced' (saturation) or a conv/s float")
    ap.add_argument("--wrong", type=float, default=0.10,
                    help="AMPD wrong-prediction rate")
    ap.add_argument("--scenario", default="classic",
                    help="'classic' or a scenario-library name")
    ap.add_argument("--seed", type=int, default=0, help="scenario seed")
    args = ap.parse_args()

    if args.scenario != "classic":
        from repro.traces import make_scenario
        trace = make_scenario(args.scenario, args.n, seed=args.seed,
                              scale="paper")
        workload = f"scenario={args.scenario} seed={args.seed}"
    elif args.rate == "paced":
        trace = generate_trace(args.n, 1.634, TraceConfig(seed=17),
                               arrival_process="paced")
        workload = "arrivals=paced"
    else:
        trace = generate_trace(args.n, float(args.rate), TraceConfig(seed=17))
        workload = f"arrivals={args.rate}"
    total = sum(c.total_input_tokens + c.total_output_tokens for c in trace)
    print(f"trace: {args.n} conversations, {total/1e6:.1f}M tokens, "
          f"{workload}")

    print(f"\n{'system':<13}{'TTFET g/p95 (s)':>20}{'lastTBT (ms)':>14}"
          f"{'E2E g (s)':>11}{'tok/J':>8}{'xfer/conv':>11}")
    for system in ("conserve", "ampd", "collocated", "full_disagg"):
        sim = paper_deployment(system, wrong_prediction_rate=args.wrong)
        # the shared Runtime contract (same call drives the real engine)
        recs = sim.serve(trace)
        s = summarize(recs, energy_joules=sim.total_energy_j(),
                      total_tokens=total)
        print(f"{system:<13}{s['ttfet_gmean']:>9.1f}/{s['ttfet_p95']:>9.1f}"
              f"{s['last_tbt_gmean']*1e3:>14.1f}{s['e2e_gmean']:>11.1f}"
              f"{s['tokens_per_joule']:>8.1f}{s['kv_transfers_per_conv']:>11.2f}")


if __name__ == "__main__":
    main()
