"""End-to-end training driver: train a ~100M-param OLMo-family model for a
few hundred steps on the synthetic LM pipeline, with checkpoints and
restart-resume. (On the CPU container, pass --small for a quick run; the
same script pjit-shards onto a TPU mesh via --arch/--mesh.)

    PYTHONPATH=src python examples/train_lm.py --steps 300 --small
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.models import build_model
from repro.train import (AdamWConfig, DataConfig, SyntheticLM, adamw_init,
                         latest_step, make_train_step, restore_checkpoint,
                         save_checkpoint)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true",
                    help="reduced config for CPU")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.small:
        cfg = get_reduced(args.arch).scaled(
            n_layers=4, d_model=128, d_ff=512, n_heads=4, n_kv_heads=4,
            head_dim=32, vocab_size=4096)
    else:
        # ~100M: olmo-family, 12L x 768
        cfg = get_config(args.arch).scaled(
            n_layers=12, d_model=768, d_ff=3072, n_heads=12, n_kv_heads=12,
            head_dim=64, vocab_size=32768, dtype="float32")
    model = build_model(cfg)
    print(f"arch={cfg.name} params={model.n_params()/1e6:.1f}M "
          f"seq={args.seq} batch={args.batch}")

    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    start = 0
    if args.resume and latest_step(args.ckpt_dir) is not None:
        start = latest_step(args.ckpt_dir)
        params, opt, extra = restore_checkpoint(args.ckpt_dir, start, params, opt)
        print(f"resumed from step {start}")

    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                  global_batch=args.batch))
    t0 = time.time()
    tokens_seen = start * args.seq * args.batch
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, m = step_fn(params, opt, batch)
        tokens_seen += args.seq * args.batch
        if (i + 1) % 20 == 0 or i == start:
            tps = tokens_seen / max(time.time() - t0, 1e-9)
            print(f"step {i+1:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  gnorm {float(m['grad_norm']):.2f}  "
                  f"{tps/1e3:.1f}k tok/s")
        if (i + 1) % args.ckpt_every == 0:
            p = save_checkpoint(args.ckpt_dir, i + 1, params, opt,
                                extra={"tokens_seen": tokens_seen})
            print(f"  checkpoint -> {p}")
    print("done")


if __name__ == "__main__":
    main()
