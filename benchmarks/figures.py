"""One benchmark function per paper figure/table (DESIGN.md §6 index).

Each emits `name,us_per_call,derived` CSV rows; heavier artifacts (full
grids, CDFs) are written under benchmarks/artifacts/. Characterization
figures (2-8) mix REAL JAX measurements on a reduced model (this container
is CPU-only) with the calibrated A40 cost model at paper scale; evaluation
figures (10-13) run the event-driven cluster runtime end to end.
"""
from __future__ import annotations

import json
import time

import numpy as np

from .common import ARTIFACTS, emit, run_system, saturation_trace, timed


# --------------------------------------------------------------------------- #
def fig01_trace_dist():
    """Fig. 1: input/output token distributions of agentic traces."""
    from repro.traces import TraceConfig, generate_trace
    trace = generate_trace(400, 1.0, TraceConfig(seed=0))
    first = np.array([c.first_input_len for c in trace])
    appends = np.array([t.append_tokens for c in trace for t in c.turns[1:]])
    outs = np.array([t.output_tokens for c in trace for t in c.turns])
    derived = (f"turn1_mean={first.mean():.0f};append_mean={appends.mean():.0f};"
               f"out_cv={outs.std()/outs.mean():.2f};"
               f"asymmetry={first.mean()/appends.mean():.0f}x")
    (ARTIFACTS / "fig01.json").write_text(json.dumps({
        "turn1_p50": float(np.percentile(first, 50)),
        "turn1_p95": float(np.percentile(first, 95)),
        "append_p50": float(np.percentile(appends, 50)),
        "out_p50": float(np.percentile(outs, 50)),
        "out_p99": float(np.percentile(outs, 99))}))
    emit("fig01_trace_dist", 0.0, derived)


def fig02_prefill_curve():
    """Fig. 2: TTFT vs input length — quadratic fit quality (paper: R²=1.0),
    prefix caching reduces TTFT to near-constant. REAL JAX timings."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.core.signals import PrefillLatencyCurve
    from repro.engine import ReplicaEngine
    from repro.models import build_model

    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ReplicaEngine(cfg, params, n_slots=2, max_ctx=4096)
    lengths = [128, 256, 512, 1024, 2048]
    lat, lat_cached = [], []
    for L in lengths:
        toks = np.arange(L, dtype=np.int32) % cfg.vocab_size
        slot = eng.kv.acquire()
        _, dt = eng.prefill_conversation(slot, toks[: L - 64])
        # warm path: append 64 tokens against the cached prefix
        _, dt_app = eng.append_prefill(slot, toks[L - 64:])
        # fresh full prefill (cold)
        slot2 = eng.kv.acquire()
        _, dt_full = eng.prefill_conversation(slot2, toks)
        eng.kv.release(slot), eng.kv.release(slot2)
        lat.append(dt_full)
        lat_cached.append(dt_app)
    curve, r2 = PrefillLatencyCurve.fit(lengths, lat)
    speedup = np.mean(np.array(lat) / np.array(lat_cached))
    # paper-scale regime (attention-dominant, >=10^4 tokens): the calibrated
    # A40 cost model, where the quadratic fit is near-exact (paper: R2=1.0)
    from repro.cluster import A40, NodeCostModel, ServedModelProfile
    cost = NodeCostModel(A40, ServedModelProfile())
    big = [1024, 4096, 8192, 16384, 32768]
    big_lat = [cost.prefill_s(L) for L in big]
    big_cached = [cost.prefill_s(L, cached_prefix=L - 256) for L in big]
    _, r2_big = PrefillLatencyCurve.fit(big, big_lat)
    big_speed = float(np.mean(np.array(big_lat) / np.array(big_cached)))
    (ARTIFACTS / "fig02.json").write_text(json.dumps(
        {"lengths": lengths, "ttft_s": lat, "ttft_cached_s": lat_cached,
         "fit": [curve.a, curve.b, curve.c], "r2_small_engine": r2,
         "paper_scale": {"lengths": big, "ttft_s": big_lat,
                         "r2": r2_big, "cache_speedup": big_speed}}))
    emit("fig02_prefill_curve", np.mean(lat) * 1e6,
         f"R2@32k={r2_big:.4f};prefix_cache_speedup@32k={big_speed:.1f}x;"
         f"R2_engine_short={r2:.2f}")


def fig03_kv_transfer():
    """Fig. 3: KV-transfer overhead — linear in tokens; fraction of TTFT
    shrinks as inputs grow (quadratic prefill dominates)."""
    from repro.cluster import A40, NodeCostModel, ServedModelProfile
    cost = NodeCostModel(A40, ServedModelProfile())
    lengths = [256, 1024, 4096, 16384, 32768]
    fracs, xfer = [], []
    for L in lengths:
        t_x = cost.kv_transfer_s(L)
        t_p = cost.prefill_s(L)
        xfer.append(t_x)
        fracs.append(t_x / (t_x + t_p))
    # linearity of transfer time
    slope = np.polyfit(lengths, xfer, 1)
    pred = np.polyval(slope, lengths)
    r2 = 1 - np.sum((np.array(xfer) - pred) ** 2) / np.var(xfer) / len(xfer)
    (ARTIFACTS / "fig03.json").write_text(json.dumps(
        {"lengths": lengths, "transfer_s": xfer, "fraction_of_ttft": fracs}))
    emit("fig03_kv_transfer", xfer[-1] * 1e6,
         f"linear_r2={r2:.4f};frac@256={fracs[0]:.2f};frac@32k={fracs[-1]:.3f}")


def fig04_tbt_heatmap():
    """Fig. 4: mean TBT across batch × context — memory-bandwidth
    saturation boundary."""
    from repro.cluster import A40, NodeCostModel, ServedModelProfile
    cost = NodeCostModel(A40, ServedModelProfile())
    batches = [1, 2, 4, 8, 16, 32, 64]
    ctxs = [1024, 4096, 16384, 65536, 262144]
    grid = [[cost.decode_iteration_s(b, b * c) for c in ctxs] for b in batches]
    sat = sum(1 for b in batches for i, c in enumerate(ctxs)
              if grid[batches.index(b)][i] > 2 * grid[0][0])
    (ARTIFACTS / "fig04.json").write_text(json.dumps(
        {"batches": batches, "ctxs": ctxs, "tbt_s": grid}))
    emit("fig04_tbt_heatmap", grid[-1][-1] * 1e6,
         f"tbt@1x1k={grid[0][0]*1e3:.1f}ms;tbt@64x256k={grid[-1][-1]*1e3:.0f}ms;"
         f"saturated_cells={sat}/{len(batches)*len(ctxs)}")


def fig05_collocation():
    """Fig. 5: collocated prefill+decode iteration latency; prefix caching
    improves collocation overhead ~an order of magnitude."""
    from repro.cluster import A40, NodeCostModel, ServedModelProfile
    cost = NodeCostModel(A40, ServedModelProfile())
    base = cost.decode_iteration_s(8, 8 * 16384)
    cold = cost.decode_iteration_s(8, 8 * 16384, prefill_chunk_tokens=2944,
                                   cached_chunk=False)
    warm = cost.decode_iteration_s(8, 8 * 16384, prefill_chunk_tokens=2944,
                                   cached_chunk=True)
    ratio = (cold - base) / max(warm - base, 1e-9)
    big_ctx = cost.decode_iteration_s(8, 262144)
    big_ctx_pf = cost.decode_iteration_s(8, 262144,
                                         prefill_chunk_tokens=2944)
    ctx_dominated = (big_ctx_pf - big_ctx) / big_ctx
    emit("fig05_collocation", cold * 1e6,
         f"cold_vs_warm_overhead={ratio:.1f}x;"
         f"prefill_share@262k_kv={ctx_dominated:.2f}")


def fig06_tbt_variance():
    """Fig. 6: iteration-level TBT variance through a long decode — REAL
    engine measurements."""
    import jax
    from repro.configs import get_reduced
    from repro.engine import ReplicaEngine
    from repro.models import build_model
    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ReplicaEngine(cfg, params, n_slots=8, max_ctx=512)
    slots = [eng.kv.acquire() for _ in range(8)]
    for s in slots:
        eng.prefill_conversation(s, np.arange(64, dtype=np.int32))
    nt = np.ones(8, np.int32)
    em = np.ones(8, bool)
    tbts = []
    for i in range(48):
        sampled, dt = eng.decode_step_all(nt, em)
        nt = sampled
        if i >= 8:  # skip warmup/compile iterations
            tbts.append(dt)
    tbts = np.array(tbts)
    emit("fig06_tbt_variance", tbts.mean() * 1e6,
         f"cv={tbts.std()/tbts.mean():.2f};p95_over_p50="
         f"{np.percentile(tbts,95)/np.percentile(tbts,50):.2f}")


def fig07_powercap_prefill():
    """Fig. 7: power capping hits uncached prefill hard, cached prefill
    barely."""
    from repro.cluster import A40, A40_CAPPED, NodeCostModel, ServedModelProfile
    m = ServedModelProfile()
    full = NodeCostModel(A40, m)
    capped = NodeCostModel(A40_CAPPED, m)
    L = 16384
    slow = capped.prefill_s(L) / full.prefill_s(L)
    slow_cached = (capped.prefill_s(L, cached_prefix=L - 256)
                   / full.prefill_s(L, cached_prefix=L - 256))
    emit("fig07_powercap_prefill", full.prefill_s(L) * 1e6,
         f"uncached_slowdown={slow:.2f}x;cached_slowdown={slow_cached:.2f}x")


def fig08_powercap_decode():
    """Fig. 8: TBT delta under the cap — marginal in the saturated
    (high batch × context) region."""
    from repro.cluster import A40, A40_CAPPED, NodeCostModel, ServedModelProfile
    m = ServedModelProfile()
    full = NodeCostModel(A40, m)
    capped = NodeCostModel(A40_CAPPED, m)
    sat = capped.decode_iteration_s(64, 64 * 16384) \
        / full.decode_iteration_s(64, 64 * 16384)
    unsat = capped.decode_iteration_s(1, 512) \
        / full.decode_iteration_s(1, 512)
    emit("fig08_powercap_decode", 0.0,
         f"saturated_slowdown={sat:.3f}x;unsaturated_slowdown={unsat:.3f}x")


# --------------------------------------------------------------------------- #
def _unloaded_baseline(trace):
    """Per-conversation interference-free execution: same turns, arrivals
    spread so nothing overlaps (one sim run)."""
    import dataclasses
    spread = [dataclasses.replace(c, arrival_s=i * 10_000.0)
              for i, c in enumerate(trace)]
    _, sim = run_system("conserve", spread)
    return {r.cid: r for r in sim.results()}


def fig10_agentic_perf():
    """Fig. 10: normalized gmean/p95 TTFET, last-turn TBT, E2E + SLO rows
    for the four systems across arrival rates (incl. the 1.634 saturation
    point). SLO = 5x each conversation's own unloaded execution (§5.3 at
    conversation granularity)."""
    from repro.core.metrics import gmean, per_conversation_slo_violations
    from repro.traces import TraceConfig, generate_trace

    rates = [0.5, 0.75, 1.0, 1.25, 1.5, 1.634]
    table = {}
    t0 = time.perf_counter()
    for rate in rates:
        proc = "paced" if rate > 1.55 else "poisson"
        trace = generate_trace(250, rate, TraceConfig(seed=17),
                               arrival_process=proc)
        base = _unloaded_baseline(trace)
        b_ttfet = gmean([b.ttfet_s for b in base.values()])
        b_tbt = gmean([b.last_turn_tbt_s for b in base.values()
                       if b.last_turn_tbt_s > 0])
        b_e2e = gmean([b.e2e_s for b in base.values()])
        for system in ("conserve", "ampd", "collocated", "full_disagg"):
            s, sim = run_system(system, trace)
            viol = per_conversation_slo_violations(sim.results(), base)
            table[f"{system}@{rate}"] = {
                "ttfet_gmean_norm": s["ttfet_gmean"] / b_ttfet,
                "ttfet_p95_norm": s["ttfet_p95"] / b_ttfet,
                "last_tbt_gmean_norm": s["last_tbt_gmean"] / max(b_tbt, 1e-9),
                "e2e_gmean_norm": s["e2e_gmean"] / b_e2e,
                "slo_viol_ttfet": viol["ttfet"],
                "slo_viol_last_tbt": viol["last_tbt"],
                "slo_viol_e2e": viol["e2e"],
            }
    dt = (time.perf_counter() - t0) * 1e6 / (len(rates) * 4)
    (ARTIFACTS / "fig10.json").write_text(json.dumps(table, indent=1))
    sat = 1.634
    cs = table[f"conserve@{sat}"]
    am = table[f"ampd@{sat}"]
    fd = table[f"full_disagg@{sat}"]
    red_p95 = 1 - cs["ttfet_p95_norm"] / am["ttfet_p95_norm"]
    red_g = 1 - cs["ttfet_gmean_norm"] / am["ttfet_gmean_norm"]
    emit("fig10_agentic_perf", dt,
         f"p95_ttfet_reduction_vs_ampd={red_p95:.1%};"
         f"gmean_reduction={red_g:.1%};"
         f"conserve_slo_viol={cs['slo_viol_ttfet']:.2f};"
         f"fd_ttfet_norm={fd['ttfet_gmean_norm']:.1f}x")


def fig11_cdfs():
    """Fig. 11: conventional per-turn TTFT/TBT distributions at the
    saturation arrival pattern."""
    from repro.core.metrics import per_turn_distributions
    trace = saturation_trace()
    out = {}
    for system in ("conserve", "ampd", "collocated", "full_disagg"):
        _, sim = run_system(system, trace)
        d = per_turn_distributions(sim.results())
        out[system] = {
            "ttft_p50": float(np.percentile(d["ttft"], 50)),
            "ttft_p75": float(np.percentile(d["ttft"], 75)),
            "ttft_p95": float(np.percentile(d["ttft"], 95)),
            "tbt_p50": float(np.percentile(d["tbt"], 50)),
            "tbt_p95": float(np.percentile(d["tbt"], 95)),
        }
    (ARTIFACTS / "fig11.json").write_text(json.dumps(out, indent=1))
    emit("fig11_cdfs", 0.0,
         f"fd_ttft_p50={out['full_disagg']['ttft_p50']:.2f}s;"
         f"cs_ttft_p50={out['conserve']['ttft_p50']:.3f}s;"
         f"fd_tbt_p50={out['full_disagg']['tbt_p50']*1e3:.1f}ms;"
         f"cs_tbt_p50={out['conserve']['tbt_p50']*1e3:.1f}ms")


def fig12_wrong_prediction():
    """Fig. 12: ConServe vs AMPD across wrong-prediction rates — latency and
    SLO degrade ~linearly; energy efficiency declines monotonically;
    ConServe is flat by construction (it makes no per-turn decision)."""
    from repro.core.metrics import per_conversation_slo_violations
    trace = saturation_trace()
    base = _unloaded_baseline(trace)
    ps = [0.0, 0.05, 0.10, 0.25, 0.50]
    rows = {}
    for p in ps:
        s, sim = run_system("ampd", trace, wrong=p)
        viol = per_conversation_slo_violations(sim.results(), base)
        rows[p] = {k: s[k] for k in
                   ("ttfet_gmean", "ttfet_p95", "e2e_gmean",
                    "tokens_per_joule", "last_tbt_gmean")}
        rows[p]["slo_viol_ttfet"] = viol["ttfet"]
        rows[p]["slo_viol_e2e"] = viol["e2e"]
    cs, sim = run_system("conserve", trace)
    cs_viol = per_conversation_slo_violations(sim.results(), base)
    cs["slo_viol_ttfet"], cs["slo_viol_e2e"] = cs_viol["ttfet"], cs_viol["e2e"]
    (ARTIFACTS / "fig12.json").write_text(json.dumps(
        {"ampd": {str(k): v for k, v in rows.items()},
         "conserve": {k: cs.get(k) for k in rows[0.0]}}, indent=1))
    # linearity of gmean TTFET in p
    xs = np.array(ps)
    ys = np.array([rows[p]["ttfet_gmean"] for p in ps])
    coef = np.polyfit(xs, ys, 1)
    r2 = 1 - np.sum((ys - np.polyval(coef, xs)) ** 2) / (np.var(ys) * len(ys))
    tpj_drop = 1 - rows[0.5]["tokens_per_joule"] / rows[0.0]["tokens_per_joule"]
    e_gap_10 = 1 - rows[0.10]["tokens_per_joule"] / cs["tokens_per_joule"]
    assert abs(rows[0.0]["ttfet_gmean"] - cs["ttfet_gmean"]) < 1e-9
    emit("fig12_wrong_prediction", 0.0,
         f"linear_r2={r2:.3f};tbt_flat={rows[0.5]['last_tbt_gmean']/rows[0.0]['last_tbt_gmean']:.2f}x;"
         f"tokjoule_drop@50%={tpj_drop:.1%};energy_gap@10%={e_gap_10:.1%}")


def fig13_hetero():
    """Fig. 13: heterogeneous tiers (full-power prefiller, capped decoders):
    tokens/joule gain at ~unchanged p95 latency; Collocated loses TTFET
    under the same cap."""
    trace = saturation_trace(n=100, seed=19)
    cs_hom, _ = run_system("conserve", trace)
    cs_het, _ = run_system("conserve", trace, heterogeneous=True)
    co_hom, _ = run_system("collocated", trace)
    co_het, _ = run_system("collocated", trace, heterogeneous=True)
    gain = cs_het["tokens_per_joule"] / cs_hom["tokens_per_joule"] - 1
    lat = cs_het["ttfet_p95"] / cs_hom["ttfet_p95"] - 1
    co_pen = co_het["ttfet_p95"] / co_hom["ttfet_p95"] - 1
    (ARTIFACTS / "fig13.json").write_text(json.dumps({
        "conserve_hom": cs_hom, "conserve_het": cs_het,
        "collocated_hom": co_hom, "collocated_het": co_het}, indent=1,
        default=float))
    emit("fig13_hetero", 0.0,
         f"tokens_per_joule_gain={gain:+.1%};p95_ttfet_delta={lat:+.1%};"
         f"collocated_ttfet_penalty={co_pen:+.1%}")


def decode_tail_bench():
    """Decode-tail tokens/s: single-step reference vs fused donated scan
    (writes BENCH_decode_tail.json at the repo root)."""
    from . import decode_tail
    decode_tail.main(quick=True)


def prefill_path_bench():
    """Prefill-path tokens/s: eager reference vs the AOT-compiled donated
    (append-)prefill programs, turn-1 and hot-prefix append scenarios
    (writes BENCH_prefill_path.json at the repo root). Series:
    `prefill_path_turn1` / `prefill_path_append` (jit vs reference tokens/s
    and speedups on the bucketed multi-turn trace; compile_s recorded
    separately and never inside a measured pass)."""
    from . import prefill_path
    prefill_path.main(quick=True)


def serve_overload_bench():
    """Saturated serving through admission backpressure on both backends
    (writes BENCH_serve_overload.json at the repo root). Series:
    `serve_overload_engine` / `serve_overload_sim` (completion + queue wait
    + p95 TTFET under 2x oversubscription, now with per-node
    masked_forward_fraction / slot_busy_fraction lane observables) and
    `serve_overload_rotation` (continuous decode rotation vs
    chunk-boundary-only admission on the staggered overload trace:
    effective decode tokens/s, masked-forward fractions, p95 queue-wait
    ratio — the rotation win in the perf trajectory)."""
    from . import serve_overload
    serve_overload.main(quick=True)


def fault_recovery_bench():
    """Failure contract on both backends (writes BENCH_fault_recovery.json
    at the repo root). Series: `fault_recovery_engine` (seeded decoder
    deaths + one armed KV-transfer fault on the real disaggregated engine:
    completion, byte-identity of recovered streams vs the failure-free run,
    recovery-latency mean/p95, replayed prefill tokens) and
    `fault_recovery_sim` (paper 4-GPU ConServe deployment: decoder death
    mid-run and the tool-deadline watchdog variant — recovered counts,
    evictions, replay charged to the prefiller)."""
    from . import fault_recovery
    fault_recovery.main(quick=True)


def prefix_reuse_bench():
    """Shared-prefix KV pool: turn-1 tokens/s for a fleet sharing one
    preamble, pooled vs no-pool, with byte-identity of the sampled streams
    asserted inside the run (writes BENCH_prefix_reuse.json at the repo
    root). Series: `prefix_reuse_turn1` (engine: pooled vs no-pool context
    tokens/s + pool hits) and `prefix_reuse_sim` (simulator mirror: pool
    hits / entries under identity keys and the cost-model cached_prefix)."""
    from . import prefix_reuse
    prefix_reuse.main(quick=True)


def live_serving_bench():
    """Async streaming gateway vs offline batch serving at equal load
    (writes BENCH_live_serving.json at the repo root). Series:
    `live_serving_engine` (two-scenario workload live through the gateway:
    per-(cid, turn) stream byte-identity vs offline replay — also under one
    injected decoder failure — p95 TTFET live vs offline, time-to-first-
    streamed-token p50/p95), `live_serving_breaker` (circuit breaker sheds
    new admissions at the queue watermark without crashing in-flight work)
    and `live_serving_sim` (paper-scale mirror: turn-level stream counts +
    the same latency deltas)."""
    from . import live_serving
    live_serving.main(quick=True)


def chaos_soak_bench():
    """Seeded chaos soak on both backends (writes BENCH_chaos_soak.json at
    the repo root). Series: `chaos_soak_engine` / `chaos_soak_sim` — one
    byte-identical fault schedule (kill -> rejoin cycle, sustained slowdown
    tripping the observed-straggler quarantine and recovering out of it,
    KV-transfer fault, tool timeout) applied mid-flight to a live
    gateway-driven multi-scenario workload; gated inside the run on
    completion, per-(cid, turn) stream identity vs the fault-free offline
    replay, zero placements on dead/quarantined nodes, and the quarantined
    replica observably serving again. Reports node-recovery latency
    p50/p95, replayed-token fraction and decoder-availability fraction."""
    from . import chaos_soak
    chaos_soak.main(quick=True)


ALL = [fig01_trace_dist, fig02_prefill_curve, fig03_kv_transfer,
       fig04_tbt_heatmap, fig05_collocation, fig06_tbt_variance,
       fig07_powercap_prefill, fig08_powercap_decode, fig10_agentic_perf,
       fig11_cdfs, fig12_wrong_prediction, fig13_hetero, decode_tail_bench,
       prefill_path_bench, serve_overload_bench, fault_recovery_bench,
       prefix_reuse_bench, live_serving_bench, chaos_soak_bench]
