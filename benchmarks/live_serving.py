"""Live-serving benchmark: the async streaming gateway vs the offline batch
path at equal load, on both backends.

A combined workload (two named scenarios from the library, disjoint cid
ranges) is served twice per backend: once offline (`Runtime.serve`, every
arrival pre-loaded) and once LIVE through `repro.serve.ServeGateway`
(staged mid-flight submissions driven by an asyncio loop, per-token
streaming off the event bus). The contract gated here:

  * every live-streamed per-(cid, turn) token stream is BYTE-IDENTICAL to
    the offline replay on the engine (turn-level counts on the sim) —
    including with one replica failure injected mid-serve;
  * p95 TTFET live vs offline at equal load (staged arrivals clamp to the
    runtime's now, so the delta is the observable cost of liveness);
  * time-to-first-streamed-token (logical first-token instant minus trace
    arrival) p50/p95 — the latency a live subscriber actually sees;
  * the circuit breaker sheds new admissions when every node's queue
    exceeds the watermark WITHOUT crashing in-flight work.

Writes BENCH_live_serving.json (BENCH_live_serving_quick.json under
--quick) at the repo root; CI runs the quick variant and gates on
completion + stream identity + a non-crashing shed.

Usage: PYTHONPATH=src python -m benchmarks.live_serving [--quick]
"""
from __future__ import annotations

import argparse
import asyncio
import json
from pathlib import Path

import numpy as np

from .common import emit

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_live_serving.json"
BENCH_QUICK_PATH = BENCH_PATH.with_name("BENCH_live_serving_quick.json")


def _workload(n_convs: int, scale: str):
    """Two scenarios from the library, disjoint cid ranges, interleaved in
    arrival time — the CI smoke contract (staggered live arrivals from
    more than one generator)."""
    from repro.traces import make_scenario
    half = n_convs // 2
    a = make_scenario("shared_preamble_fleet", half, seed=2, scale=scale)
    b = make_scenario("pareto_burst", n_convs - half, seed=7, scale=scale,
                      cid_offset=1000, arrival_offset_s=0.05)
    return a + b


def _stream_latencies(gw, convs):
    lat = [gw.first_token_t[c.cid] - c.arrival_s for c in convs
           if c.cid in gw.first_token_t]
    return {
        "first_stream_token_p50_s": float(np.percentile(lat, 50)),
        "first_stream_token_p95_s": float(np.percentile(lat, 95)),
    }


def _engine_live(n_convs: int):
    import jax
    from repro.configs import get_reduced
    from repro.core import make_scheduler
    from repro.core.metrics import summarize
    from repro.engine import EngineServer, ReplicaEngine
    from repro.models import build_model
    from repro.serve import serve_scenario_live

    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def mk(n_slots=8):
        reps = [ReplicaEngine(cfg, params, n_slots=n_slots, max_ctx=1024,
                              replica_id=0, role="prefill")] + [
            ReplicaEngine(cfg, params, n_slots=n_slots, max_ctx=1024,
                          replica_id=i, role="decode") for i in (1, 2)]
        return EngineServer(make_scheduler("conserve"), reps,
                            record_tokens=True, strict_accounting=True)

    off_srv = mk()
    off_recs = off_srv.serve(_workload(n_convs, "engine"))
    offline_tokens = {k: list(v) for k, v in off_srv.sampled_tokens.items()}
    off_s = summarize(off_recs)

    convs = _workload(n_convs, "engine")
    live_srv = mk()
    recs, gw, client = serve_scenario_live(live_srv, convs)
    live_s = summarize(recs)
    identical = (gw.streams == offline_tokens
                 and client.collected == offline_tokens)

    # same live drive with a decoder dying mid-serve: deterministic replay
    # must re-stream the interrupted turn byte-identically through the bus
    fail_srv = mk().fail_replica(1, at_s=0.4)
    frecs, fgw, fclient = serve_scenario_live(
        fail_srv, _workload(n_convs, "engine"))
    fail_identical = (fgw.streams == offline_tokens
                      and fclient.collected == offline_tokens)

    return {
        "n_conversations": n_convs,
        "complete_live": len(recs),
        "complete_failure": len(frecs),
        "streams_identical": bool(identical),
        "streams_identical_under_failure": bool(fail_identical),
        "n_recovered_under_failure": int(sum(
            1 for r in frecs if r.recovered)),
        "ttfet_p95_offline_s": off_s["ttfet_p95"],
        "ttfet_p95_live_s": live_s["ttfet_p95"],
        **_stream_latencies(gw, convs),
        "events": dict(gw.events_seen),
    }


def _engine_breaker(n_convs: int):
    """Flood a 2-slot mixed pair through the gateway with watermark 0:
    submissions once both queues are deep must SHED (GatewayOverloaded),
    and everything admitted still completes."""
    import jax
    from repro.configs import get_reduced
    from repro.core import make_scheduler
    from repro.engine import EngineServer, ReplicaEngine
    from repro.models import build_model
    from repro.serve import GatewayOverloaded, ServeGateway
    from repro.traces import make_scenario

    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reps = [ReplicaEngine(cfg, params, n_slots=1, max_ctx=1024,
                          replica_id=i, role="mixed") for i in (0, 1)]
    srv = EngineServer(make_scheduler("conserve"), reps,
                       record_tokens=True, strict_accounting=True)
    burst = make_scenario("pareto_burst", n_convs, seed=9, scale="engine")
    for c in burst:
        c.arrival_s = 0.0
    extra = make_scenario("pareto_burst", 4, seed=11, scale="engine",
                          cid_offset=5000)

    async def run():
        gw = ServeGateway(srv, shed_watermark=0, max_events_per_tick=8)
        gw.start()
        gw.submit(burst)
        shed = 0
        # probe with one extra conversation per tick until the breaker
        # fires (both single-slot queues go deep within a few ticks)
        for _ in range(400):
            await asyncio.sleep(0)
            if not extra:
                break
            try:
                gw.submit([extra[0]])
                extra.pop(0)
            except GatewayOverloaded:
                shed += 1
                break
        recs = await gw.drain()
        return gw, recs, shed

    gw, recs, shed = asyncio.run(run())
    srv.check_accounting()
    return {
        "n_burst": n_convs,
        "n_shed": gw.n_shed,
        "shed_raised": shed,
        "complete": len(recs),
        "all_admitted_complete": len(recs) == gw.n_submitted,
    }


def _sim_live(n_convs: int):
    from repro.cluster import paper_deployment
    from repro.core.metrics import summarize
    from repro.serve import serve_scenario_live

    off = paper_deployment("conserve")
    off_recs = off.serve(_workload(n_convs, "paper"))
    off_counts = {(r.cid, i): t.n_output_tokens
                  for r in off_recs for i, t in enumerate(r.turns)}
    off_s = summarize(off_recs)

    convs = _workload(n_convs, "paper")
    recs, gw, _ = serve_scenario_live(paper_deployment("conserve"), convs)
    live_counts = {k: sum(v) for k, v in gw.streams.items()}
    live_s = summarize(recs)
    return {
        "n_conversations": n_convs,
        "complete_live": len(recs),
        "turn_streams_identical": live_counts == off_counts,
        "ttfet_p95_offline_s": off_s["ttfet_p95"],
        "ttfet_p95_live_s": live_s["ttfet_p95"],
        **_stream_latencies(gw, convs),
        "events": dict(gw.events_seen),
    }


def main(quick: bool = False):
    import jax

    eng = _engine_live(n_convs=8 if quick else 16)
    emit("live_serving_engine",
         eng["ttfet_p95_live_s"] * 1e6,
         f"complete={eng['complete_live']}/{eng['n_conversations']};"
         f"identical={eng['streams_identical']};"
         f"identical_failure={eng['streams_identical_under_failure']};"
         f"ttfet_p95_off={eng['ttfet_p95_offline_s']:.3f}s;"
         f"first_stream_p95={eng['first_stream_token_p95_s']:.3f}s")

    brk = _engine_breaker(n_convs=8 if quick else 12)
    emit("live_serving_breaker",
         0.0,
         f"shed={brk['n_shed']};"
         f"admitted_complete={brk['all_admitted_complete']}")

    sim = _sim_live(n_convs=12 if quick else 40)
    emit("live_serving_sim",
         sim["ttfet_p95_live_s"] * 1e6,
         f"complete={sim['complete_live']}/{sim['n_conversations']};"
         f"identical={sim['turn_streams_identical']};"
         f"ttfet_p95_off={sim['ttfet_p95_offline_s']:.3f}s;"
         f"first_stream_p95={sim['first_stream_token_p95_s']:.3f}s")

    payload = {"backend": jax.default_backend(), "quick": quick,
               "engine": eng, "breaker": brk, "simulator": sim}
    (BENCH_QUICK_PATH if quick else BENCH_PATH).write_text(
        json.dumps(payload, indent=1))
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(**vars(ap.parse_args()))
