"""Shared-prefix KV pool benchmark: turn-1 prefill throughput when many
conversations open with the same preamble (system prompt / tool schemas).

A fleet of conversations shares ONE preamble; each adds a distinct task
delta. Two jit engines run the identical turn-1 schedule:

  * `no_pool`:  every conversation prefills its full context from scratch
    (the split at the preamble boundary still happens — the split, not the
    pool, fixes the math — but the preamble forward is recomputed);
  * `pooled`:   the first conversation populates the node-level prefix KV
    pool; every later conversation folds the pooled rows in one donated
    dispatch and forwards only its delta.

The measured quantity is turn-1 CONTEXT tokens/s: total context tokens
landed in slots divided by wall prefill time, so the pooled win is exactly
the recomputation it skipped. Sampled turn-1 tokens must be byte-identical
across the two engines (pool on/off never changes the stream), and the
gate `pooled_tok_s >= no_pool_tok_s` at >= 8 conversations sharing one
preamble is what CI enforces.

A ClusterSimulator section mirrors the same fleet through the sim pool
(identity keys, cost model cached_prefix) and reports hits + delta-charged
admission tokens, so both backends' pool accounting lands in the same
trajectory file.

Emits CSV rows through benchmarks.common and writes BENCH_prefix_reuse.json
at the repo root (quick runs write BENCH_prefix_reuse_quick.json).

Usage: PYTHONPATH=src python -m benchmarks.prefix_reuse [--quick]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from .common import emit

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_prefix_reuse.json"
BENCH_QUICK_PATH = BENCH_PATH.with_name("BENCH_prefix_reuse_quick.json")


def _engine(cfg, params, pool_tokens: int, max_ctx: int):
    from repro.engine import ReplicaEngine
    return ReplicaEngine(cfg, params, n_slots=4, max_ctx=max_ctx,
                         prefill_mode="jit", prefix_pool_tokens=pool_tokens)


def _fleet(n_convs: int, preamble_len: int, delta_len: int, vocab: int):
    """One shared preamble + per-conversation deltas, deterministic."""
    rng = np.random.RandomState(7)
    pre = rng.randint(0, vocab, size=preamble_len).astype(np.int32)
    deltas = [rng.randint(0, vocab, size=delta_len).astype(np.int32)
              for _ in range(n_convs)]
    return pre, deltas


def _run_fleet(eng, pre, deltas):
    """Every conversation's turn-1 prefill with the preamble split, slot
    released immediately (the fleet is larger than n_slots — pool reuse,
    not slot reuse, is what's under test). Returns (context_tokens,
    wall_s, [sampled token per conversation])."""
    toks, total, total_s = [], 0, 0.0
    for delta in deltas:
        slot = eng.kv.acquire()
        full = np.concatenate([pre, delta])
        tok, dt = eng.prefill_conversation(slot, full, prefix_len=len(pre))
        toks.append(int(tok))
        total += len(full)
        total_s += dt
        eng.kv.release(slot)
    return total, total_s, toks


def _measure(eng, pre, deltas, repeats: int):
    """Warm pass (compiles every bucket + populates/exercises the pool),
    then best-of-N measured passes. The pool survives across passes — the
    steady state being measured IS the warm-pool state; the cold populate
    cost is charged once in the warm-up like compile time."""
    _run_fleet(eng, pre, deltas)
    best = None
    for _ in range(max(1, repeats)):
        r = _run_fleet(eng, pre, deltas)
        if best is None or r[1] < best[1]:
            best = r
    return best


def _sim_fleet(n_convs: int, preamble_len: int, delta_len: int):
    """Mirror fleet through ClusterSimulator: one prefiller + one pooled
    prefiller, conversations arriving with a shared preamble identity.
    Returns the pool/accounting observables."""
    from repro.cluster import A40, NodeCostModel, ServedModelProfile
    from repro.cluster.simulator import ClusterSimulator, SimNode
    from repro.core import make_scheduler
    from repro.core.conversation import Conversation, Turn

    cost = NodeCostModel(A40, ServedModelProfile())
    nodes = [SimNode(node_id=0, role="prefill", cost=cost,
                     prefix_pool_tokens=4 * preamble_len),
             SimNode(node_id=1, role="decode", cost=cost)]
    convs = [Conversation(
        cid=i, arrival_s=0.05 * i,
        turns=[Turn(append_tokens=preamble_len + delta_len,
                    output_tokens=8, tool_time_s=0.0)],
        preamble_id=0, preamble_tokens=preamble_len)
        for i in range(n_convs)]
    sim = ClusterSimulator(make_scheduler("conserve"), nodes)
    sim.serve(convs)
    pf = sim.nodes[0].state
    done = sum(1 for s in sim.sessions.values() if s.done)
    return {"completed": done,
            "pool_hits": pf.pooled_prefix_hits,
            "pool_entries": pf.pooled_prefix_entries,
            "pooled_tokens": pf.pooled_prefix_tokens}


def main(quick: bool = False):
    import jax
    from repro.configs import get_reduced
    from repro.models import build_model

    n_convs = 8 if quick else 16
    preamble_len, delta_len = (96, 40) if quick else (192, 64)
    repeats = 3 if quick else 5
    max_ctx = 256 if quick else 512

    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pre, deltas = _fleet(n_convs, preamble_len, delta_len, cfg.vocab_size)

    out = {}
    for name, pool_tokens in (("pooled", 4 * preamble_len), ("no_pool", 0)):
        eng = _engine(cfg, params, pool_tokens, max_ctx)
        tokens, wall_s, toks = _measure(eng, pre, deltas, repeats)
        out[name] = {
            "context_tokens": tokens, "wall_s": wall_s,
            "tok_s": tokens / wall_s,
            "sampled": toks,
            "pool_hits": (eng.prefix_pool.total_hits
                          if eng.prefix_pool else 0),
            "pooled_prefix_tokens": int(eng.n_pooled_prefix_tokens),
            "compile_s": round(eng.compile_s, 3),
        }

    if out["pooled"]["sampled"] != out["no_pool"]["sampled"]:
        raise AssertionError(
            "pool on/off changed the sampled turn-1 stream: "
            f"{out['pooled']['sampled']} vs {out['no_pool']['sampled']}")

    speedup = out["pooled"]["tok_s"] / out["no_pool"]["tok_s"]
    emit("prefix_reuse_turn1",
         out["no_pool"]["wall_s"] / n_convs * 1e6,
         f"pooled={out['pooled']['tok_s']:.0f}tok/s;"
         f"no_pool={out['no_pool']['tok_s']:.0f}tok/s;"
         f"speedup={speedup:.2f}x;hits={out['pooled']['pool_hits']}")

    sim = _sim_fleet(n_convs, preamble_len, delta_len)
    emit("prefix_reuse_sim", sim["pool_hits"],
         f"completed={sim['completed']}/{n_convs};"
         f"hits={sim['pool_hits']};entries={sim['pool_entries']}")

    payload = {"model": "qwen3-0.6b(reduced)",
               "backend": jax.default_backend(), "quick": quick,
               "n_conversations": n_convs,
               "preamble_tokens": preamble_len, "delta_tokens": delta_len,
               "repeats": repeats,
               "pooled": {k: v for k, v in out["pooled"].items()
                          if k != "sampled"},
               "no_pool": {k: v for k, v in out["no_pool"].items()
                           if k != "sampled"},
               "stream_identical": True,
               "speedup": round(speedup, 3),
               "sim": sim}
    (BENCH_QUICK_PATH if quick else BENCH_PATH).write_text(
        json.dumps(payload, indent=1))
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
