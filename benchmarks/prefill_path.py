"""Prefill-path throughput benchmark: before/after numbers for the
zero-dispatch prefill rebuild.

Measures prefill tokens/s on a bucketed multi-turn trace for
  * `reference`: the eager per-op path — op-by-op dispatch, host-side
    `write_prefill` KV copy, append-prefill reading its prefix through the
    host-side `export_slot_full` full-buffer view;
  * `jit`: the AOT-compiled donated programs — one dispatch per prefill,
    logits gather + greedy sampling on device, the per-slot KV write a
    dynamic-slice scatter *inside* the program, and the append prefix a
    dynamic slice of the slot's own rows trimmed to its ctx bucket.

Two scenarios, mirroring the paper's two prefill classes:
  * `turn1`: fresh conversation prefills across the length buckets
    (compute-bound TTFT work, what the prefiller tier saturates on);
  * `append`: turn-2+ appends against hot prefixes of growing context
    (the ConServe pinned-tail fast path — short inputs, large prefixes).

Both run best-of-N warm passes over identical (length, prefix) schedules;
AOT/op compile time is reported separately (`compile_s`) and is never part
of a measured pass — the first full schedule is a discarded warm-up.

Emits CSV rows through benchmarks.common and writes BENCH_prefill_path.json
at the repo root so the perf trajectory is tracked PR over PR.

Usage: PYTHONPATH=src python -m benchmarks.prefill_path [--quick]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from .common import emit

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_prefill_path.json"
# quick (CI smoke) runs write a separate file so they never clobber the
# committed full-grid trajectory record
BENCH_QUICK_PATH = BENCH_PATH.with_name("BENCH_prefill_path_quick.json")

# bucketed multi-turn trace: (turn-1 length, [append lengths...]) per
# conversation — lengths chosen to exercise several PREFILL_BUCKETS and,
# through the growing prefix, several append ctx buckets
TRACE = ((40, (14, 30)),
         (90, (24,)),
         (200, (14, 60)),
         (450, (30,)))
TRACE_QUICK = ((40, (14,)),
               (90, (24,)))


def _engines(quick: bool):
    import jax
    from repro.configs import get_reduced
    from repro.engine import ReplicaEngine
    from repro.models import build_model

    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_ctx = 512 if quick else 1024
    return {mode: ReplicaEngine(cfg, params, n_slots=8, max_ctx=max_ctx,
                                prefill_mode=mode)
            for mode in ("jit", "reference")}, cfg


def _run_schedule(eng, trace):
    """One full pass over the trace: every conversation's turn-1 prefill
    followed by its appends (prefix grows in place), slots released at the
    end so passes are identical. Turn-1 and append time accumulate
    SEPARATELY from the engine's own per-call dt (compile time is charged
    to compile_s by contract, never to dt), so the two prefill classes get
    their own tokens/s without cross-schedule subtraction."""
    t1_tokens = t1_s = app_tokens = app_s = 0
    slots = []
    for ci, (t1, appends) in enumerate(trace):
        slot = eng.kv.acquire()
        slots.append(slot)
        prompt = (np.arange(t1, dtype=np.int32) * (ci + 3)) % eng.cfg.vocab_size
        _, dt = eng.prefill_conversation(slot, prompt)
        t1_tokens += t1
        t1_s += dt
        for ai, app in enumerate(appends):
            toks = (np.arange(app, dtype=np.int32) * (ci + 5) + ai) \
                % eng.cfg.vocab_size
            _, dt = eng.append_prefill(slot, toks)
            app_tokens += app
            app_s += dt
    for s in slots:
        eng.kv.release(s)
    return t1_tokens, t1_s, app_tokens, app_s


def _measure(eng, trace, repeats: int):
    """Warm pass (compiles every bucket the schedule hits), then best-of-N
    measured passes (fastest total) — same protocol as the decode_tail
    benchmark, so the two phases' trajectories are comparable."""
    _run_schedule(eng, trace)                 # warm-up: compile + execute
    best = None
    for _ in range(max(1, repeats)):
        r = _run_schedule(eng, trace)
        if best is None or r[1] + r[3] < best[1] + best[3]:
            best = r
    return best


def main(quick: bool = False):
    import jax

    trace = TRACE_QUICK if quick else TRACE
    repeats = 3 if quick else 5
    engines, cfg = _engines(quick)

    out = {}
    for mode, eng in engines.items():
        t1_tokens, t1_s, app_tokens, app_s = _measure(eng, trace, repeats)
        out[mode] = {
            "turn1_tokens": t1_tokens, "turn1_s": t1_s,
            "turn1_tok_s": t1_tokens / t1_s,
            "append_tokens": app_tokens, "append_s": app_s,
            "append_tok_s": app_tokens / app_s,
            "total_tok_s": (t1_tokens + app_tokens) / (t1_s + app_s),
            "compile_s": round(eng.compile_s, 3),
        }

    jit, ref = out["jit"], out["reference"]
    speedup = jit["total_tok_s"] / ref["total_tok_s"]
    speedup_t1 = jit["turn1_tok_s"] / ref["turn1_tok_s"]
    speedup_app = jit["append_tok_s"] / ref["append_tok_s"]
    # both CSV rows report per-CALL reference microseconds (the shared
    # us_per_call column), so the trajectory stays comparable if the trace
    # ever changes shape
    n_t1 = max(len(trace), 1)
    n_app = max(sum(len(a) for _, a in trace), 1)
    emit("prefill_path_turn1", ref["turn1_s"] / n_t1 * 1e6,
         f"jit={jit['turn1_tok_s']:.0f}tok/s;ref={ref['turn1_tok_s']:.0f}"
         f"tok/s;speedup={speedup_t1:.1f}x")
    emit("prefill_path_append", ref["append_s"] / n_app * 1e6,
         f"jit={jit['append_tok_s']:.0f}tok/s;ref={ref['append_tok_s']:.0f}"
         f"tok/s;speedup={speedup_app:.1f}x")

    payload = {"model": "qwen3-0.6b(reduced)",
               "backend": jax.default_backend(), "quick": quick,
               "trace": [[t1, list(a)] for t1, a in trace],
               "repeats": repeats,
               "jit": jit, "reference": ref,
               "speedup": round(speedup, 2),
               "speedup_turn1": round(speedup_t1, 2),
               "speedup_append": round(speedup_app, 2)}
    (BENCH_QUICK_PATH if quick else BENCH_PATH).write_text(
        json.dumps(payload, indent=1))
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
