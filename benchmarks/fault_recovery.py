"""Fault-recovery smoke benchmark: seeded replica failures mid-serve must
COMPLETE on both backends, and every recovered per-(cid, turn) token stream
on the real engine must be BYTE-IDENTICAL to the failure-free run — the
observation-only failure contract (journaled deterministic replay, no
predicted state ever reconstructed).

Scenarios:
  * engine: disaggregated 1 prefiller + 2 decoders (real JAX). A
    failure-free pass establishes the reference streams and the serving
    span; seeded failure schedules then kill a decoder at fractions of that
    span (plus one armed KV-transfer fault) and every stream is compared
    byte for byte. Recovery latency (trigger -> interrupted decode
    runnable) and replayed prefill tokens are recorded.
  * simulator: the paper's 4-GPU ConServe deployment with a decoder death
    mid-run, and a tool-deadline watchdog variant (evictions + replay on
    tool return) — same Runtime failure contract at cluster scale.

Writes BENCH_fault_recovery.json (BENCH_fault_recovery_quick.json under
--quick) at the repo root; CI runs the quick variant and fails unless every
submitted conversation completes under failures on BOTH backends AND the
engine's recovered streams are byte-identical.

Usage: PYTHONPATH=src python -m benchmarks.fault_recovery [--quick]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from .common import emit

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_fault_recovery.json"
BENCH_QUICK_PATH = BENCH_PATH.with_name("BENCH_fault_recovery_quick.json")


def _trace(n):
    from repro.core.conversation import Conversation, Turn
    return [Conversation(cid=i, arrival_s=i * 1e-6, turns=[
        Turn(append_tokens=24 + 4 * (i % 5), output_tokens=10,
             tool_time_s=0.05),
        Turn(append_tokens=10 + 2 * (i % 4), output_tokens=8,
             tool_time_s=0.0)]) for i in range(n)]


def _engine_recovery(n_convs: int, n_schedules: int, seed: int = 0):
    import jax
    from repro.configs import get_reduced
    from repro.core import make_scheduler
    from repro.core.metrics import summarize
    from repro.engine import EngineServer, ReplicaEngine
    from repro.models import build_model

    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def serve(fail=None, transfer_faults=0):
        reps = [ReplicaEngine(cfg, params, n_slots=2 * n_convs, max_ctx=256,
                              replica_id=0, role="prefill"),
                ReplicaEngine(cfg, params, n_slots=max(2, n_convs // 2),
                              max_ctx=256, replica_id=1, role="decode"),
                ReplicaEngine(cfg, params, n_slots=max(2, n_convs // 2),
                              max_ctx=256, replica_id=2, role="decode")]
        srv = EngineServer(make_scheduler("conserve"), reps,
                           record_tokens=True, strict_accounting=True)
        if fail is not None:
            srv.fail_replica(*fail)
        if transfer_faults:
            srv.inject_transfer_faults(transfer_faults)
        recs = srv.serve(_trace(n_convs))
        return srv, recs

    base_srv, base_recs = serve()
    span = max(t.last_token_s for r in base_recs for t in r.turns)
    rng = np.random.RandomState(seed)
    schedules = [(int(rng.randint(1, 3)), float(rng.uniform(0.05, 0.95)))
                 for _ in range(n_schedules)]
    runs, identical, total_recovered = [], True, 0
    rec_lat = []
    for i, (victim, frac) in enumerate(schedules):
        srv, recs = serve(fail=(victim, frac * span),
                          transfer_faults=1 if i == 0 else 0)
        same = srv.sampled_tokens == base_srv.sampled_tokens
        identical = identical and same
        s = summarize(recs)
        total_recovered += s["n_recovered"]
        rec_lat += [l for r in recs for l in r.recovery_latency_s]
        runs.append({
            "victim": victim, "fail_at_s": round(frac * span, 4),
            "completed": len(recs), "streams_identical": same,
            "n_recovered": s["n_recovered"],
            "n_transfer_retries": srv.n_transfer_retries,
            "recovery_latency_mean_s": s["recovery_latency_mean_s"],
            "replayed_prefill_tokens": sum(
                st.replayed_prefill_tokens for st in srv.states.values()),
        })
    return {
        "n_conversations": n_convs,
        "n_schedules": n_schedules,
        "baseline_span_s": round(span, 4),
        "all_complete": all(r["completed"] == n_convs for r in runs),
        "streams_identical": identical,
        "total_recovered": total_recovered,
        "recovery_latency_mean_s": float(np.mean(rec_lat)) if rec_lat else 0.0,
        "recovery_latency_p95_s": float(np.percentile(rec_lat, 95))
        if rec_lat else 0.0,
        "runs": runs,
    }


def _sim_recovery(n_convs: int):
    from repro.cluster import paper_deployment
    from repro.core.metrics import summarize
    from repro.traces import TraceConfig, generate_trace

    trace = generate_trace(n_convs, 1.2,
                           TraceConfig(seed=21, mean_turns=5.0,
                                       tool_mean_s=4.0))
    sim = paper_deployment("conserve")
    sim.submit(trace)
    sim.inject_failure(node_id=1, at_s=15.0)
    sim.run()
    recs = sim.results()
    s = summarize(recs)
    fail = {
        "completed": len(recs),
        "n_recovered": s["n_recovered"],
        "recovery_latency_mean_s": s["recovery_latency_mean_s"],
        "recovery_latency_p95_s": s["recovery_latency_p95_s"],
        "replayed_prefill_tokens":
            sim.nodes[0].state.replayed_prefill_tokens,
    }
    wd = paper_deployment("conserve", tool_deadline_s=2.0,
                          tool_timeout_action="evict")
    wd_trace = generate_trace(n_convs, 1.5,
                              TraceConfig(seed=31, mean_turns=4.0,
                                          tool_mean_s=10.0))
    wd.submit(wd_trace).run()
    ws = summarize(wd.results())
    watchdog = {
        "completed": len(wd.results()),
        "n_tool_evictions": ws["n_tool_evictions"],
        "n_recovered": ws["n_recovered"],
        "recovery_latency_mean_s": ws["recovery_latency_mean_s"],
    }
    return {"n_conversations": n_convs, "decoder_death": fail,
            "tool_watchdog": watchdog}


def main(quick: bool = False):
    import jax

    eng = _engine_recovery(n_convs=4 if quick else 8,
                           n_schedules=2 if quick else 4)
    emit("fault_recovery_engine",
         eng["recovery_latency_mean_s"] * 1e6,
         f"complete={eng['all_complete']};"
         f"identical={eng['streams_identical']};"
         f"recovered={eng['total_recovered']};"
         f"rec_lat_p95={eng['recovery_latency_p95_s']:.3f}s")

    sim = _sim_recovery(20 if quick else 40)
    emit("fault_recovery_sim",
         sim["decoder_death"]["recovery_latency_mean_s"] * 1e6,
         f"complete={sim['decoder_death']['completed']}"
         f"/{sim['n_conversations']};"
         f"recovered={sim['decoder_death']['n_recovered']};"
         f"evictions={sim['tool_watchdog']['n_tool_evictions']};"
         f"replayed={sim['decoder_death']['replayed_prefill_tokens']}tok")

    payload = {"backend": jax.default_backend(), "quick": quick,
               "engine": eng, "simulator": sim}
    (BENCH_QUICK_PATH if quick else BENCH_PATH).write_text(
        json.dumps(payload, indent=1))
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
