"""Chaos soak benchmark: a seeded, byte-identical fault schedule — one
kill -> rejoin cycle, one sustained slowdown that must trip the
observed-straggler quarantine (and recover out of it), one KV-transfer
fault and one tool timeout — applied mid-flight to a live gateway-driven
multi-scenario workload on BOTH backends.

The gate is the full chaos contract from `repro.chaos.check_chaos_invariants`:

  * every submitted conversation COMPLETES;
  * every per-(cid, turn) stream is BYTE-IDENTICAL to the fault-free
    offline replay (token ids on the engine, per-turn counts on the sim)
    under `strict_accounting=True`;
  * ZERO placements land on dead or quarantined nodes (asserted inline by
    the `PlacementMonitor` at every admission event);
  * the killed node rejoins from dead, the slowed node is quarantined
    PURELY from its observed TBT EMA vs the fleet median, rejoins when the
    observation recovers, and serves again (a held-back conversation wave
    submits at the observed rejoin, landing on the cold node).

Reported metrics: node recovery latency p50/p95 (failure -> from_dead
join), replayed-token fraction (replayed prefill work over all prefill
work), and decoder-availability fraction (per-node alive AND ACTIVE time
integrated from the observed lifecycle log).

Writes BENCH_chaos_soak.json (BENCH_chaos_soak_quick.json under --quick)
at the repo root; CI runs the quick variant and fails unless completion +
stream identity + zero bad placements + the quarantine round-trip hold on
both backends.

Usage: PYTHONPATH=src python -m benchmarks.chaos_soak [--quick]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from .common import emit

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_chaos_soak.json"
BENCH_QUICK_PATH = BENCH_PATH.with_name("BENCH_chaos_soak_quick.json")

# schedule shapes: the kill -> rejoin cycle completes BEFORE the slowdown
# window opens, so the fleet never has the straggler and the corpse out of
# service at once, and the slowdown lifts while the quarantined node still
# holds observable tails (the EMA needs ~12 observed chunks to decay back
# under the rejoin threshold). The ranges differ per backend because the
# backends' activity profiles differ: the simulator serves continuously
# across its span, while the engine's logical span is dominated by the
# inflated tool wait — its decode activity is an early burst plus the
# watchdog-replay tail — so the engine cycle runs earlier and its slowdown
# window is much wider to guarantee overlap with observed chunks.
_SIM_SCHED_KW = dict(
    kill_frac_range=(0.06, 0.12),
    rejoin_delay_frac_range=(0.08, 0.14),
    slowdown_start_range=(0.28, 0.36),
    slowdown_len_range=(0.18, 0.28),
    slowdown_factor_range=(8.0, 12.0),
    transfer_frac_range=(0.15, 0.55),
)
_ENGINE_SCHED_KW = dict(
    kill_frac_range=(0.03, 0.05),
    rejoin_delay_frac_range=(0.04, 0.07),
    slowdown_start_range=(0.02, 0.04),
    slowdown_len_range=(0.38, 0.45),
    slowdown_factor_range=(5.5, 6.5),
    transfer_frac_range=(0.15, 0.55),
)
_QUARANTINE_KW = dict(quarantine_k=3.0, quarantine_window=2)


def _workload(n_convs: int, scale: str):
    """First wave: two scenarios from the library, disjoint cid ranges,
    interleaved arrivals (the soak acceptance requires >= 2 scenarios)."""
    from repro.traces import make_scenario
    half = n_convs // 2
    a = make_scenario("shared_preamble_fleet", half, seed=2, scale=scale)
    b = make_scenario("pareto_burst", n_convs - half, seed=7, scale=scale,
                      cid_offset=1000, arrival_offset_s=0.05)
    return a + b


def _engine_first_wave(n_convs: int):
    """Engine first wave: three scenario slices with staggered LOGICAL
    arrivals (0 / 0.3 / 0.6 s). Engine decode drains a slice in ~0.3 s of
    logical time, so the stagger keeps decoders continuously busy across
    most of the span — the slowdown window is guaranteed to overlap
    observed chunks, and the slice landing after the rejoin re-warms the
    revived node's EMA (a cold node is exactly what min-KV binding
    prefers), restoring the fleet-median baseline the quarantine trigger
    compares against."""
    from repro.traces import make_scenario
    quarter = n_convs // 4
    # slice A is pareto_burst ON PURPOSE: its per-conversation KV is
    # balanced, so min-KV binding alternates decoders evenly and the
    # slowdown victim owns enough resident work to keep producing the
    # chunk observations the rejoin rule feeds on (a shared-preamble slice
    # here skews binding away from whichever node imports the preamble
    # first)
    a = make_scenario("pareto_burst", n_convs - 2 * quarter, seed=2,
                      scale="engine")
    b = make_scenario("shared_preamble_fleet", quarter, seed=7,
                      scale="engine", cid_offset=1000, arrival_offset_s=0.3)
    c = make_scenario("supervisor_worker", quarter, seed=11,
                      scale="engine", cid_offset=2000, arrival_offset_s=0.6)
    return a + b + c


def _wave(n_convs: int, scale: str, cid_offset: int, seed: int):
    from repro.traces import make_scenario
    return make_scenario("pareto_burst", n_convs, seed=seed, scale=scale,
                         cid_offset=cid_offset)


def _metrics(runtime, monitor, records, convs, decode_ids):
    rec_lat = monitor.recovery_latencies()
    conv_lat = [l for r in records for l in r.recovery_latency_s]
    avail = monitor.availability_timeline(decode_ids, 0.0, runtime.now_s)
    total_in = sum(t.append_tokens for c in convs for t in c.turns)
    replayed = sum(st.replayed_prefill_tokens
                   for st in runtime.view._nodes.values())
    return {
        "node_recovery_latency_p50_s": float(np.percentile(rec_lat, 50))
        if rec_lat else 0.0,
        "node_recovery_latency_p95_s": float(np.percentile(rec_lat, 95))
        if rec_lat else 0.0,
        "conv_recovery_latency_p95_s": float(np.percentile(conv_lat, 95))
        if conv_lat else 0.0,
        "replayed_prefill_tokens": int(replayed),
        "replayed_token_fraction": replayed / max(replayed + total_in, 1),
        "decoder_availability_fraction": float(np.mean(list(avail.values()))),
        "decoder_availability_by_node": {
            int(k): round(v, 4) for k, v in avail.items()},
    }


def _engine_chaos(n_convs: int, seed: int):
    import jax
    from repro.chaos import (apply_tool_timeouts, arm_schedule,
                             check_chaos_invariants,
                             generate_chaos_schedule, run_chaos)
    from repro.configs import get_reduced
    from repro.core import make_scheduler
    from repro.engine import EngineServer, ReplicaEngine
    from repro.models import build_model

    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # >> engine-scale tool_mean_s=0.05, but small enough that the victim's
    # inflated wait (3x deadline) doesn't swallow the span in dead air — a
    # few extra honest evictions on the exp(0.05) tail are fine, the
    # watchdog replay path preserves stream identity by construction
    deadline = 0.12

    def mk(**kw):
        # max_decode_chunk=4 densifies the chunk observations the
        # quarantine trigger consumes (chunking never changes tokens)
        reps = [ReplicaEngine(cfg, params, n_slots=8, max_ctx=1024,
                              replica_id=0, role="prefill")] + [
            ReplicaEngine(cfg, params, n_slots=8, max_ctx=1024,
                          replica_id=i, role="decode") for i in (1, 2)]
        return EngineServer(make_scheduler("conserve"), reps,
                            record_tokens=True, strict_accounting=True,
                            max_decode_chunk=4, rotation_min_chunk=4, **kw)

    # fault ordering keeps a live ACTIVE decoder at every instant of the
    # two-decoder run: the slowdown victim stays ACTIVE (merely slow) until
    # its EMA trips, which takes long enough that the killed peer has
    # already rejoined by then
    schedule = generate_chaos_schedule(seed, [1, 2], **_ENGINE_SCHED_KW)
    first = apply_tool_timeouts(_engine_first_wave(n_convs), schedule,
                                deadline)
    w2 = _wave(max(2, n_convs // 4), "engine", 9000, 13)
    w3 = _wave(max(2, n_convs // 4), "engine", 9500, 17)
    everyone = first + w2 + w3

    base = mk()
    base_recs = base.serve(everyone)
    span = max(t.last_token_s for r in base_recs for t in r.turns)
    baseline_streams = {k: list(v) for k, v in base.sampled_tokens.items()}

    srv = mk(tool_deadline_s=deadline, tool_timeout_action="evict",
             **_QUARANTINE_KW)
    arm_schedule(srv, schedule, span)
    # submit the whole first wave in one batch: its staggered LOGICAL
    # arrivals then land on the heap deterministically instead of being
    # clamped to wherever the wall-clock drive loop happens to be
    res = run_chaos(srv, first, schedule, span, second_wave=w2,
                    quarantine_wave=w3, stagger=len(first))
    evidence = check_chaos_invariants(res.records, res.gateway, res.monitor,
                                      schedule, everyone, baseline_streams)
    srv.check_accounting()
    return {
        "n_conversations": len(everyone),
        "schedule_digest": schedule.digest,
        "baseline_span_s": round(span, 4),
        "all_complete": len(res.records) == len(everyone),
        "streams_identical": True,  # check_chaos_invariants raised otherwise
        "zero_bad_placements": not res.monitor.violations,
        "evidence": evidence,
        **_metrics(srv, res.monitor, res.records, everyone, [1, 2]),
    }


def _sim_chaos(n_convs: int, seed: int):
    from repro.chaos import (apply_tool_timeouts, arm_schedule,
                             check_chaos_invariants,
                             generate_chaos_schedule, run_chaos)
    from repro.cluster.deployment import build_cluster, make_scheduler

    deadline = 6.0  # >> paper-scale tool_mean_s=1.5: only the victim trips

    def mk(**kw):
        return build_cluster(make_scheduler("conserve"), n_prefill=1,
                             n_decode=3, strict_accounting=True, **kw)

    schedule = generate_chaos_schedule(seed + 1, [1, 2, 3], **_SIM_SCHED_KW)
    first = apply_tool_timeouts(_workload(n_convs, "paper"), schedule,
                                deadline)
    w2 = _wave(max(2, n_convs // 4), "paper", 9000, 13)
    w3 = _wave(max(2, n_convs // 4), "paper", 9500, 17)
    everyone = first + w2 + w3

    base = mk()
    base_recs = base.serve(everyone)
    span = max(t.last_token_s for r in base_recs for t in r.turns)
    base_counts = {(r.cid, i): t.n_output_tokens
                   for r in base_recs for i, t in enumerate(r.turns)}

    sim = mk(tool_deadline_s=deadline, tool_timeout_action="evict",
             **_QUARANTINE_KW)
    arm_schedule(sim, schedule, span)
    res = run_chaos(sim, first, schedule, span, second_wave=w2,
                    quarantine_wave=w3)
    counts = {k: sum(v) for k, v in res.gateway.streams.items()}
    evidence = check_chaos_invariants(res.records, res.gateway, res.monitor,
                                      schedule, everyone, base_counts,
                                      streams=counts)
    sim.check_accounting()
    return {
        "n_conversations": len(everyone),
        "schedule_digest": schedule.digest,
        "baseline_span_s": round(span, 4),
        "all_complete": len(res.records) == len(everyone),
        "streams_identical": True,
        "zero_bad_placements": not res.monitor.violations,
        "evidence": evidence,
        **_metrics(sim, res.monitor, res.records, everyone, [1, 2, 3]),
    }


def main(quick: bool = False):
    import jax

    eng = _engine_chaos(n_convs=15 if quick else 24, seed=20260807)
    emit("chaos_soak_engine",
         eng["node_recovery_latency_p95_s"] * 1e6,
         f"complete={eng['all_complete']};"
         f"identical={eng['streams_identical']};"
         f"quarantines={eng['evidence']['n_quarantines']};"
         f"joins={eng['evidence']['n_joins']};"
         f"avail={eng['decoder_availability_fraction']:.3f};"
         f"replayed_frac={eng['replayed_token_fraction']:.4f}")

    sim = _sim_chaos(n_convs=16 if quick else 32, seed=20260807)
    emit("chaos_soak_sim",
         sim["node_recovery_latency_p95_s"] * 1e6,
         f"complete={sim['all_complete']};"
         f"identical={sim['streams_identical']};"
         f"quarantines={sim['evidence']['n_quarantines']};"
         f"joins={sim['evidence']['n_joins']};"
         f"avail={sim['decoder_availability_fraction']:.3f};"
         f"replayed_frac={sim['replayed_token_fraction']:.4f}")

    payload = {"backend": jax.default_backend(), "quick": quick,
               "engine": eng, "simulator": sim}
    (BENCH_QUICK_PATH if quick else BENCH_PATH).write_text(
        json.dumps(payload, indent=1))
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
