"""Decode-tail throughput benchmark: before/after numbers for the zero-copy
decode rebuild.

Measures decode tokens/s vs active-slot count and live KV length for
  * `reference`: the pre-PR single-step path — one jitted dispatch + host
    sync + host-side argmax per token, cache folded via the copying
    `append_step`;
  * `fused`: the donated in-place multi-token scan (`decode_steps`) — one
    dispatch per chunk, on-device sampling fed back, per-slot scatter fused
    into the jit program, cache reads trimmed to the live-context bucket.

A second, staggered-finish scenario replays the agentic worst case — slots
finishing 1-32 steps apart — under three policies:
  * `reference`: one dispatch per token with a shrinking emit mask;
  * `min_collapse`: the PR 1 server policy, every chunk capped at
    min(remaining) over active slots (one nearly-finished turn collapses
    the chunk for the whole batch);
  * `ragged`: the current policy — chunks sized from max(remaining), each
    slot consuming only its per-slot share and freezing mid-scan.

Emits CSV rows through benchmarks.common and writes BENCH_decode_tail.json
at the repo root so the perf trajectory is tracked PR over PR.

Usage: PYTHONPATH=src python -m benchmarks.decode_tail [--quick]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from .common import emit

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_decode_tail.json"
# quick (CI smoke) runs write a separate file so they never clobber the
# committed full-grid trajectory record
BENCH_QUICK_PATH = BENCH_PATH.with_name("BENCH_decode_tail_quick.json")


def _make_engine(cfg, params, n_slots, max_ctx, n_active, prompt_len):
    from repro.engine import ReplicaEngine
    eng = ReplicaEngine(cfg, params, n_slots=n_slots, max_ctx=max_ctx)
    nt = np.zeros(n_slots, np.int32)
    em = np.zeros(n_slots, bool)
    for i in range(n_active):
        slot = eng.kv.acquire()
        prompt = (np.arange(prompt_len, dtype=np.int32) * (i + 3)) \
            % cfg.vocab_size
        tok, _ = eng.prefill_conversation(slot, prompt)
        nt[slot], em[slot] = int(tok), True
    return eng, nt, em


def _snapshot(eng):
    import jax
    import jax.numpy as jnp
    return (jax.tree_util.tree_map(jnp.array, eng.kv.caches),
            eng.kv.lengths.copy())


def _restore(eng, snap):
    import jax
    import jax.numpy as jnp
    caches, lengths = snap
    # fresh copies: decode_steps donates its cache input, so the snapshot
    # itself must never be handed to the engine
    eng.kv.caches = jax.tree_util.tree_map(jnp.array, caches)
    eng.kv.lengths = lengths.copy()


def _run_reference(eng, nt, em, n_tokens):
    nt = nt.copy()
    t0 = time.perf_counter()
    for _ in range(n_tokens):
        sampled, _ = eng.decode_step_all_reference(nt, em)
        nt[em] = sampled[em]
    return time.perf_counter() - t0


def _run_fused(eng, nt, em, n_tokens, chunk):
    nt = nt.copy()
    done = 0
    t0 = time.perf_counter()
    while done < n_tokens:
        n = min(chunk, n_tokens - done)
        seq, _ = eng.decode_steps(nt, em, n)
        nt[em] = seq[n - 1][em]
        done += n
    return time.perf_counter() - t0


# staggered-finish outputs: slots finishing 1-32 steps apart (the raggedness
# the paper's agentic traces exhibit between turns of different tasks)
STAGGERED_OUTPUTS = (1, 3, 6, 10, 14, 19, 25, 32)


def _bucket_floor(n):
    # the SAME floor the server uses — policy and replay stay locked
    from repro.engine.replica import decode_chunk_floor
    return decode_chunk_floor(n)


def _run_reference_staggered(eng, nt, em, outputs):
    """One dispatch per token; slots drop out of the emit mask as their
    outputs complete."""
    nt, left, active = nt.copy(), outputs.copy(), em.copy()
    t0 = time.perf_counter()
    while active.any():
        sampled, _ = eng.decode_step_all_reference(nt, active)
        for s in np.flatnonzero(active):
            nt[s] = sampled[s]
            left[s] -= 1
            if left[s] <= 0:
                active[s] = False
    return time.perf_counter() - t0


def _run_fused_min_collapse(eng, nt, em, outputs, chunk):
    """PR 1 server policy: every chunk capped at min(remaining) over active
    slots — the nearly-finished slot drags the whole batch back toward
    single-step dispatches."""
    nt, left, active = nt.copy(), outputs.copy(), em.copy()
    t0 = time.perf_counter()
    while active.any():
        n = _bucket_floor(min(int(left[active].min()), chunk))
        seq, _ = eng.decode_steps(nt, active, n)
        for s in np.flatnonzero(active):
            nt[s] = seq[n - 1, s]
            left[s] -= n
            if left[s] <= 0:
                active[s] = False
    return time.perf_counter() - t0


def _run_fused_ragged(eng, nt, em, outputs, chunk):
    """Current server policy: chunk sized from max(remaining)
    (bucket-floored), each slot consuming only its own per-slot share and
    freezing mid-scan once it is done."""
    nt, left, active = nt.copy(), outputs.copy(), em.copy()
    t0 = time.perf_counter()
    while active.any():
        n = _bucket_floor(min(int(left[active].max()), chunk))
        rem = np.minimum(np.where(active, left, 0), n).astype(np.int32)
        seq, _ = eng.decode_steps(nt, active, rem)
        for s in np.flatnonzero(active):
            took = int(rem[s])
            nt[s] = seq[took - 1, s]
            left[s] -= took
            if left[s] <= 0:
                active[s] = False
    return time.perf_counter() - t0


def _measure(run, eng, nt, em, *args, repeats: int = 1):
    """Warm along the exact length trajectory (compiles every chunk / ctx
    bucket the measured run will hit), then restore the KV snapshot and
    time the steady state (best of `repeats` — policy comparisons use
    best-of-N so scheduler jitter on shared CI runners does not swamp the
    dispatch-count difference being measured)."""
    snap = _snapshot(eng)
    run(eng, nt, em, *args)          # warm-up pass: compile + execute
    _restore(eng, snap)
    dt = float("inf")
    for _ in range(max(1, repeats)):
        dt = min(dt, run(eng, nt, em, *args))  # measured: steady state
        _restore(eng, snap)
    return dt


def main(quick: bool = False):
    import jax
    from repro.configs import get_reduced
    from repro.models import build_model

    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    n_slots, max_ctx, chunk = 8, 512, 16
    slot_counts = (8,) if quick else (1, 4, 8)
    prompt_lens = (96,) if quick else (48, 96)
    n_tokens = 32 if quick else 64

    points = []
    compile_s = 0.0  # AOT compile seconds summed over EVERY engine built
    for n_active in slot_counts:
        for prompt_len in prompt_lens:
            eng, nt, em = _make_engine(cfg, params, n_slots, max_ctx,
                                       n_active, prompt_len)
            ref_s = _measure(_run_reference, eng, nt, em, n_tokens)
            fus_s = _measure(_run_fused, eng, nt, em, n_tokens, chunk)
            ref_tps = n_active * n_tokens / ref_s
            fus_tps = n_active * n_tokens / fus_s
            pt = {"n_active": n_active, "prompt_len": prompt_len,
                  "chunk": chunk, "n_tokens": n_tokens,
                  "reference_tok_s": ref_tps, "fused_tok_s": fus_tps,
                  "speedup": fus_tps / ref_tps}
            points.append(pt)
            compile_s += eng.compile_s
            emit(f"decode_tail_b{n_active}_l{prompt_len}",
                 ref_s / n_tokens * 1e6,
                 f"ref={ref_tps:.1f}tok/s;fused={fus_tps:.1f}tok/s;"
                 f"speedup={pt['speedup']:.2f}x")

    # staggered-finish scenario: ragged per-slot chunks vs the old
    # min-collapsed chunking vs the per-token reference (CI gates on
    # ragged >= reference; the PR acceptance bar is ragged >= 2x
    # min-collapse)
    stag_chunk = 32  # the server's default max_decode_chunk
    outs = np.zeros(n_slots, np.int32)
    outs[: len(STAGGERED_OUTPUTS)] = STAGGERED_OUTPUTS
    # short post-tool contexts: the memory-bound regime where dispatch
    # overhead (what min-collapse multiplies) dominates the forward cost
    eng, nt, em = _make_engine(cfg, params, n_slots, max_ctx,
                               len(STAGGERED_OUTPUTS), 32)
    total = int(outs.sum())
    ref_s = _measure(_run_reference_staggered, eng, nt, em, outs,
                     repeats=5)
    mc_s = _measure(_run_fused_min_collapse, eng, nt, em, outs, stag_chunk,
                    repeats=5)
    rg_s = _measure(_run_fused_ragged, eng, nt, em, outs, stag_chunk,
                    repeats=5)
    staggered = {"outputs": list(STAGGERED_OUTPUTS), "chunk": stag_chunk,
                 "total_tokens": total,
                 "reference_tok_s": total / ref_s,
                 "min_collapse_tok_s": total / mc_s,
                 "ragged_tok_s": total / rg_s,
                 "ragged_vs_min_collapse": mc_s / rg_s,
                 "ragged_vs_reference": ref_s / rg_s}
    emit("decode_tail_staggered", rg_s / total * 1e6,
         f"ragged={total / rg_s:.1f}tok/s;min_collapse={total / mc_s:.1f}"
         f"tok/s;ref={total / ref_s:.1f}tok/s;"
         f"ragged_vs_min_collapse={mc_s / rg_s:.2f}x")

    compile_s += eng.compile_s  # the staggered-scenario engine
    payload = {"model": "qwen3-0.6b(reduced)", "backend": jax.default_backend(),
               "n_slots": n_slots, "max_ctx": max_ctx, "quick": quick,
               "points": points, "staggered": staggered,
               "compile_s": round(compile_s, 3)}
    (BENCH_QUICK_PATH if quick else BENCH_PATH).write_text(
        json.dumps(payload, indent=1))
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
