"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

For every compiled (arch × shape × mesh=16x16) cell:
    compute term    = HLO_FLOPs / peak_FLOPs          [s]
    memory term     = HLO_bytes / HBM_bw              [s]
    collective term = collective_bytes / link_bw      [s]
All three use PER-DEVICE quantities: `compiled.cost_analysis()` and the
post-SPMD HLO describe one device's program, so dividing by per-chip peak
directly yields per-chip time (equivalent to the global/(chips·BW) form).

Also reports MODEL_FLOPS = 6·N·D (train, dense) / 6·N_active·D (MoE) or the
serve-side analogue, and the ratio MODEL_FLOPS/HLO_FLOPs — how much compiled
compute is "useful" (catches remat/dispatch/padding waste).

Collective-byte accounting: result-buffer bytes per collective op (operand ==
result for all-reduce/permute/all-to-all; all-gather counts the gathered
buffer ≈ wire bytes; reduce-scatter undercounts ×n but is rare here).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"
DRYRUN = ARTIFACTS / "dryrun"

PEAK_FLOPS = 197e12      # bf16 / chip (TPU v5e)
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link (ICI)
N_DEV = {"16x16": 256, "2x16x16": 512}


def model_flops_per_device(arch: str, shape_name: str, n_dev: int) -> float:
    """Analytic 'useful' FLOPs for the cell, per device."""
    import sys
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
    from repro.configs import get_config, get_shape
    from repro.models.config import ATTN_GLOBAL, ATTN_LOCAL, ATTN_MLA, RGLRU, RWKV6

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n_active = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len

    def attn_flops(tokens: int, ctx: int) -> float:
        f = 0.0
        for kind in cfg.layer_kinds():
            if kind == ATTN_GLOBAL:
                f += 4.0 * tokens * ctx * cfg.n_heads * cfg.head_dim
            elif kind == ATTN_LOCAL:
                w = min(cfg.window or ctx, ctx)
                f += 4.0 * tokens * w * cfg.n_heads * cfg.head_dim
            elif kind == ATTN_MLA:
                f += 4.0 * tokens * ctx * cfg.n_heads * cfg.kv_lora_rank
            elif kind == RWKV6:
                hs = cfg.rwkv_head_size
                f += 2.0 * tokens * (cfg.d_model // hs) * hs * hs * 3
            elif kind == RGLRU:
                f += 8.0 * tokens * cfg.lru_width
        return f

    if shape.kind == "train":
        total = 6.0 * n_active * B * S + 3.0 * attn_flops(B * S, S // 2)
    elif shape.kind == "prefill":
        total = 2.0 * n_active * B * S + attn_flops(B * S, S // 2)
    else:  # decode: one token per sequence against ctx=S
        total = 2.0 * n_active * B + attn_flops(B, S)
    return total / n_dev


def load_cells(mesh: str = "16x16", variant: str = "base") -> List[Dict]:
    out = []
    for f in sorted(DRYRUN.glob(f"*__{mesh}__{variant}.json")):
        out.append(json.loads(f.read_text()))
    return out


def _analytic_remainders(arch: str, shape_name: str, n_dev: int) -> Dict:
    """Costs hidden inside INNER scans that neither the main measurement nor
    the (unrolled-layer) depth probes can see more than once:
      * flash-attention q/kv chunk loops (probes run attention block-full, so
        per-group attention IS counted; only the main cell's 1-body residue
        differs — negligible, ignored);
      * the chunked-vocab loss scan (train cells): (n_chunks-1) additional
        chunk bodies of logits fwd+bwd matmuls and their bytes."""
    import sys
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
    from repro.configs import get_config, get_shape
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if shape.kind != "train":
        return {"flops": 0.0, "bytes": 0.0}
    chunk = 512
    n_chunks = max(shape.seq_len // chunk, 1)
    B, V, D = shape.global_batch, cfg.padded_vocab, cfg.d_model
    # fwd logits + dL/dh + dL/dW per chunk (3 matmul passes)
    per_chunk_flops = 3 * 2.0 * B * chunk * D * V / n_dev
    # logits materialized fp32 (rw) + W read per chunk
    per_chunk_bytes = (2 * 4.0 * B * chunk * V + 2.0 * D * V) / n_dev
    return {"flops": (n_chunks - 1) * per_chunk_flops,
            "bytes": (n_chunks - 1) * per_chunk_bytes}


def corrected(rec: Dict) -> Dict[str, float]:
    """Loop-aware correction: XLA cost analysis counts while-loop bodies
    once. The dry-run's depth probes (1 vs 2 layer groups, layers UNROLLED
    and attention block-full so every FLOP is visible) measure the true
    per-group cost; we extrapolate X + (G-1)·(X_g2 - X_g1) and add the
    analytic loss-scan remainder."""
    out = {"flops": rec["flops"], "bytes": rec["bytes_accessed"],
           "coll": rec["collective_total"]}
    p = rec.get("probes") or {}
    g = p.get("n_groups", 1)
    if g > 1 and "g1" in p and "g2" in p:
        out["flops"] += (g - 1) * max(
            p["g2"]["flops"] - p["g1"]["flops"], 0.0)
        out["bytes"] += (g - 1) * max(
            p["g2"]["bytes_accessed"] - p["g1"]["bytes_accessed"], 0.0)
        out["coll"] += (g - 1) * max(
            p["g2"]["collective_total"] - p["g1"]["collective_total"], 0)
    rem = _analytic_remainders(rec["arch"], rec["shape"], rec["n_devices"])
    out["flops"] += rem["flops"]
    out["bytes"] += rem["bytes"]
    return out


def analytic_bytes_per_device(arch: str, shape_name: str, n_dev: int,
                              kv_dtype_bytes: float = 2.0) -> float:
    """HBM traffic model for train/prefill cells (the measured byte counters
    are loop-blind, and measurement-mode probes materialize full-softmax
    scores, inflating their deltas). Decode cells use MEASURED bytes (their
    programs have no layer scan undercount that matters — cache reads
    dominate and are counted).

    train:   weights 4 reads (fwd + remat-refwd + dL/dx + dL/dW) + grad write
             + AdamW (m,n read+write fp32, param read+write) ≈ 30B/param;
             activations ~6 hidden-size tensors/layer × (write+read) × bf16;
             + chunked-loss traffic.
    prefill: weights 1 read; activations 1 write+read; KV cache write;
             flash K/V re-reads (nq passes)."""
    import sys
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
    from repro.configs import get_config, get_shape
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    B, S = shape.global_batch, shape.seq_len
    P_dev = cfg.param_count() * 2.0 / 16  # bf16, TP=16 (dp replicates)
    act = B * S * cfg.d_model * 2.0 / n_dev  # one hidden-sized tensor
    L = cfg.n_layers
    kv_write = cfg.kv_bytes_per_token() * B * S / n_dev
    nq = max(S // 256, 1)
    flash_rereads = (2.0 * S * max(cfg.n_heads, 1) * cfg.head_dim * 2.0
                     * nq * B / n_dev) * sum(
        1 for k in cfg.layer_kinds() if k.startswith("attn"))
    if shape.kind == "train":
        w_io = 30.0 * P_dev / 2.0 * 2.0  # ≈30 bytes/param incl. fp32 opt
        a_io = 6.0 * 2.0 * L * act * 2.0  # 6 tensors/layer, write+read, ×2 for bwd
        loss = 3 * 2.0 * B * S * cfg.padded_vocab * 4.0 / n_dev / 8  # chunked
        return w_io + a_io + loss + 3.0 * flash_rereads
    if shape.kind == "prefill":
        return P_dev + 2.0 * 4.0 * L * act + kv_write + flash_rereads
    return 0.0


def analyze(rec: Dict) -> Optional[Dict]:
    if not rec.get("supported"):
        return None
    c = corrected(rec)
    t_comp = c["flops"] / PEAK_FLOPS
    kind = "decode"
    if rec["shape"].startswith("train"):
        kind = "train"
    elif rec["shape"].startswith("prefill"):
        kind = "prefill"
    if kind == "decode":
        mem_bytes = rec["bytes_accessed"]  # measured exactly
    else:
        mem_bytes = analytic_bytes_per_device(rec["arch"], rec["shape"],
                                              rec["n_devices"])
    t_mem = mem_bytes / HBM_BW
    t_coll = c["coll"] / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"],
                                rec["n_devices"])
    useful = mf / c["flops"] if c["flops"] > 0 else float("nan")
    step_time = max(terms.values())
    # roofline fraction: useful model flops per sec over peak, at the step
    # time the dominant term implies (perfect overlap assumption)
    mfu = mf / step_time / PEAK_FLOPS if step_time > 0 else 0.0
    advice = {
        "compute": "reduce recompute (remat policy) / pad waste; fuse matmuls",
        "memory": "shrink temporaries (flash attention custom-vjp, smaller "
                  "loss chunks) or raise arithmetic intensity",
        "collective": "reshard to cut all-gathers (kv-head layout, "
                      "activation sharding constraints) / overlap collectives",
    }[bottleneck]
    return {
        "arch": rec["arch"], "shape": rec["shape"], "variant": rec["variant"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "bottleneck": bottleneck, "model_flops_per_dev": mf,
        "useful_flops_ratio": useful, "mfu_bound": mfu,
        "advice": advice,
        "argument_gb": (rec["memory"]["argument_bytes"] or 0) / 1e9,
        "temp_gb": (rec["memory"]["temp_bytes"] or 0) / 1e9,
    }


def table(variant: str = "base") -> List[Dict]:
    rows = []
    for rec in load_cells("16x16", variant):
        a = analyze(rec)
        if a:
            rows.append(a)
    return rows


def markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | bound | "
           "useful/HLO | MFU-bound |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['bottleneck']}** | {r['useful_flops_ratio']:.2f} | "
            f"{r['mfu_bound']:.1%} |")
    return "\n".join(lines)


def main(emit=None):
    rows = table()
    md = markdown(rows)
    (ARTIFACTS / "roofline.md").write_text(md + "\n")
    (ARTIFACTS / "roofline.json").write_text(json.dumps(rows, indent=1))
    from collections import Counter
    bounds = Counter(r["bottleneck"] for r in rows)
    worst = min(rows, key=lambda r: r["mfu_bound"])
    msg = (f"cells={len(rows)};bounds={dict(bounds)};"
           f"worst_mfu={worst['arch']}/{worst['shape']}="
           f"{worst['mfu_bound']:.1%}")
    if emit:
        emit("roofline", 0.0, msg)
    else:
        print(md)
        print(msg)
    return rows


if __name__ == "__main__":
    main()
