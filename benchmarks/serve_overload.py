"""Saturated-serving smoke benchmark: overload (concurrent conversations
>= 2x the decoder KV slots) must COMPLETE through admission-queue
backpressure on both backends — the workload class that used to crash the
engine with "no free KV slots" and silently overcommit the simulator.

Records queue-wait and p95 TTFET under saturation, plus the per-node lane
observables (`masked_forward_fraction`, `slot_busy_fraction`) that make the
decode-rotation win visible in the perf trajectory:
  * engine: one mixed real-JAX replica with few KV slots, arrivals packed
    at the trace head, 2x oversubscribed — every conversation beyond the
    slot count waits in the admission queue and is re-offered as
    conversations finish;
  * simulator: a disaggregated deployment whose decoders declare finite
    slots, same 2x oversubscription through the identical Runtime contract;
  * staggered rotation scenario: >= 2x oversubscribed single mixed replica
    serving staggered output lengths, run with continuous decode rotation
    (adaptive chunk cuts + mid-tail refill) vs the chunk-boundary-only
    admission baseline — EFFECTIVE decode tokens/s (live tokens per second
    of decode-engine time: masked no-op forwards and dispatch overhead both
    count against it) and p95 queue wait for each.

Writes BENCH_serve_overload.json (BENCH_serve_overload_quick.json under
--quick) at the repo root; CI runs the quick variant and fails unless every
submitted conversation completes (no slot-overflow crash, no stuck
admission queue) AND rotation's effective tokens/s stays at or above the
chunk-boundary baseline on the staggered trace.

Usage: PYTHONPATH=src python -m benchmarks.serve_overload [--quick]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from .common import emit

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_serve_overload.json"
BENCH_QUICK_PATH = BENCH_PATH.with_name("BENCH_serve_overload_quick.json")


def _overload_trace(n_convs: int, seed: int = 0):
    from repro.traces import TraceConfig, generate_trace
    tc = TraceConfig(seed=seed, first_input_median=40, first_input_sigma=0.3,
                     first_input_max=80, append_median=10, append_sigma=0.3,
                     append_max=20, output_median=8, output_sigma=0.6,
                     output_max=24, mean_turns=2.0, max_turns=3,
                     tool_mean_s=0.0)
    # arrivals packed at the head: all n_convs are concurrently live
    return generate_trace(n_convs, 1e9, cfg=tc,
                          arrival_process="saturation")


def _summary(runtime, recs, n_convs, n_slots):
    from repro.core.metrics import p95
    from repro.core.runtime import DONE
    waits = sorted(runtime.queue_waits().values())
    ttfet = [r.ttfet_s for r in recs]
    done = sum(s.done for s in runtime.sessions.values())
    return {
        "n_conversations": n_convs,
        "decoder_slots": n_slots,
        "oversubscription": n_convs / n_slots,
        "completed": len(recs),
        "sessions_done": done,
        "queued_at_least_once": int(sum(w > 0 for w in waits)),
        "deferred_admissions": runtime.n_deferred_admissions,
        "queue_wait_mean_s": float(np.mean(waits)),
        "queue_wait_p95_s": p95(waits),
        "queue_wait_max_s": float(waits[-1]) if waits else 0.0,
        "ttfet_p95_s": p95(ttfet),
        # per-node lane observables: how busy the decode rotation kept its
        # KV slots (prefill-only nodes report 0/0 — they never decode)
        "lane_observables": {
            str(n.node_id): {
                "masked_forward_fraction": round(
                    n.masked_forward_fraction, 4),
                "slot_busy_fraction": round(n.slot_busy_fraction, 4),
            } for n in runtime.view.nodes()},
    }


def _engine_overload(n_slots: int, n_convs: int):
    import jax
    from repro.configs import get_reduced
    from repro.core import make_scheduler
    from repro.engine import EngineServer, ReplicaEngine
    from repro.models import build_model

    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rep = ReplicaEngine(cfg, params, n_slots=n_slots, max_ctx=256,
                        replica_id=0, role="mixed")
    srv = EngineServer(make_scheduler("conserve"), [rep],
                       strict_accounting=True)
    recs = srv.serve(_overload_trace(n_convs))
    return _summary(srv, recs, n_convs, n_slots)


# staggered single-turn outputs for the rotation comparison: early finishes
# strand lanes inside long chunks under chunk-boundary admission, while the
# queue of parked conversations supplies the rotation's mid-tail refills
STAGGERED_OUTPUTS = (6, 10, 14, 19, 25, 32, 40, 48)


def _staggered_trace(n_convs: int):
    from repro.core.conversation import Conversation, Turn
    return [Conversation(cid=i, arrival_s=i * 1e-9, turns=[
        Turn(append_tokens=12 + (i % 5) * 2,
             output_tokens=STAGGERED_OUTPUTS[i % len(STAGGERED_OUTPUTS)],
             tool_time_s=0.0)])
        for i in range(n_convs)]


def _staggered_rotation(n_slots: int, n_convs: int, repeats: int = 3):
    """Rotation on vs off (chunk-boundary-only admission) on the SAME
    staggered overload trace and replica shape. Effective decode tokens/s =
    live decoded tokens per second of decode-engine time — masked no-op
    forwards and dispatch overhead both land in the denominator, so neither
    policy can hide its cost.

    Measurement discipline: one replica per config (compiled buckets and
    the eager prefill path stay warm across passes — slots fully drain at
    conversation end, so replicas are reusable), one discarded warm pair,
    then `repeats` measured passes ALTERNATING between the configs, taking
    each config's BEST pass — machine-load noise on shared runners is
    one-sided, so best-of-N recovers the compute floor (the same
    discipline decode_tail's policy comparison uses). The lane observables
    are structural (event-order determined), the clocks are real wall
    time."""
    import jax
    from repro.configs import get_reduced
    from repro.core import make_scheduler
    from repro.core.metrics import p95
    from repro.engine import EngineServer, ReplicaEngine
    from repro.models import build_model

    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    trace = _staggered_trace(n_convs)
    engines = {rot: ReplicaEngine(cfg, params, n_slots=n_slots, max_ctx=256,
                                  replica_id=0, role="mixed")
               for rot in (False, True)}

    def one_pass(rotation: bool):
        rep = engines[rotation]
        rep.decode_s = rep.compute_s = 0.0
        rep.n_decode_tokens = rep.n_prefill_tokens = 0
        srv = EngineServer(make_scheduler("conserve"), [rep],
                           strict_accounting=True, rotation=rotation)
        recs = srv.serve(trace)
        assert len(recs) == n_convs
        waits = sorted(srv.queue_waits().values())
        st = srv.states[0]
        return {
            "effective_decode_tok_s": rep.n_decode_tokens / rep.decode_s,
            "decode_tokens": rep.n_decode_tokens,
            "decode_s": round(rep.decode_s, 4),
            "decode_scan_steps": st.decode_scan_steps,
            "makespan_s": round(
                max(t.last_token_s for r in recs for t in r.turns), 4),
            "queue_wait_p95_s": p95(waits),
            "masked_forward_fraction": round(st.masked_forward_fraction, 4),
            "slot_busy_fraction": round(st.slot_busy_fraction, 4),
        }

    one_pass(False), one_pass(True)  # warm pair, discarded
    passes = {False: [], True: []}
    for _ in range(max(1, repeats)):
        for rot in (False, True):
            passes[rot].append(one_pass(rot))

    def agg(rot):
        # report the best pass VERBATIM (decode_tokens / decode_s /
        # effective_decode_tok_s stay mutually consistent), plus the
        # cross-pass queue-wait floor as its own clearly-named field
        ps = passes[rot]
        out = dict(max(ps, key=lambda p: p["effective_decode_tok_s"]))
        out["queue_wait_p95_best_s"] = min(p["queue_wait_p95_s"]
                                           for p in ps)
        return out

    rot, bound = agg(True), agg(False)
    return {
        "n_conversations": n_convs,
        "decoder_slots": n_slots,
        "oversubscription": n_convs / n_slots,
        "outputs_cycle": list(STAGGERED_OUTPUTS),
        "repeats": max(1, repeats),
        "rotation": rot,
        "chunk_boundary": bound,
        "rotation_speedup": (rot["effective_decode_tok_s"]
                             / bound["effective_decode_tok_s"]),
        "queue_wait_p95_ratio": (rot["queue_wait_p95_best_s"]
                                 / max(bound["queue_wait_p95_best_s"],
                                       1e-9)),
    }


def _sim_overload(n_slots_per_decoder: int, n_convs: int):
    from repro.cluster import A40, NodeCostModel, ServedModelProfile
    from repro.cluster.simulator import ClusterSimulator, SimNode
    from repro.core import make_scheduler
    from repro.traces import TraceConfig, generate_trace

    model = ServedModelProfile()
    nodes = [SimNode(node_id=0, role="prefill",
                     cost=NodeCostModel(A40, model))]
    nodes += [SimNode(node_id=i, role="decode",
                      cost=NodeCostModel(A40, model),
                      n_slots=n_slots_per_decoder) for i in (1, 2)]
    sim = ClusterSimulator(make_scheduler("conserve"), nodes)
    # long tool waits keep KV pinned (the paper's agentic residency), so
    # concurrent residency really reaches 2x the declared decoder slots
    trace = generate_trace(n_convs, 1e9, TraceConfig(seed=3, mean_turns=4.0,
                                                     tool_mean_s=8.0),
                           arrival_process="saturation")
    recs = sim.serve(trace)
    return _summary(sim, recs, n_convs, 2 * n_slots_per_decoder)


def main(quick: bool = False):
    import jax

    n_slots = 4
    n_convs = 8 if quick else 16   # >= 2x decoder slots, the acceptance bar
    eng = _engine_overload(n_slots, n_convs)
    emit("serve_overload_engine", eng["queue_wait_mean_s"] * 1e6,
         f"completed={eng['completed']}/{n_convs};"
         f"queued={eng['queued_at_least_once']};"
         f"ttfet_p95={eng['ttfet_p95_s']:.3f}s;"
         f"qwait_p95={eng['queue_wait_p95_s']:.3f}s")

    sim = _sim_overload(4, 16 if quick else 32)
    emit("serve_overload_sim", sim["queue_wait_mean_s"] * 1e6,
         f"completed={sim['completed']}/{sim['n_conversations']};"
         f"queued={sim['queued_at_least_once']};"
         f"ttfet_p95={sim['ttfet_p95_s']:.3f}s")

    stag = _staggered_rotation(n_slots=8, n_convs=16 if quick else 24,
                               repeats=3 if quick else 5)
    emit("serve_overload_rotation",
         1e6 / stag["rotation"]["effective_decode_tok_s"],
         f"rotation={stag['rotation']['effective_decode_tok_s']:.1f}tok/s;"
         f"boundary={stag['chunk_boundary']['effective_decode_tok_s']:.1f}"
         f"tok/s;speedup={stag['rotation_speedup']:.2f}x;"
         f"masked={stag['rotation']['masked_forward_fraction']:.3f}"
         f"vs{stag['chunk_boundary']['masked_forward_fraction']:.3f};"
         f"qwait_p95_ratio={stag['queue_wait_p95_ratio']:.2f}")

    payload = {"backend": jax.default_backend(), "quick": quick,
               "engine": eng, "simulator": sim, "staggered": stag}
    (BENCH_QUICK_PATH if quick else BENCH_PATH).write_text(
        json.dumps(payload, indent=1))
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
