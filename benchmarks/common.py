"""Shared benchmark plumbing: timing, CSV emission, standard deployments."""
from __future__ import annotations

import sys
import time
from pathlib import Path
from typing import Callable, Dict, List

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"
ARTIFACTS.mkdir(exist_ok=True)

_rows: List[str] = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    _rows.append(row)
    print(row, flush=True)


def timed(fn: Callable, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6  # microseconds


def rows() -> List[str]:
    return list(_rows)


def saturation_trace(n=250, seed=17):
    """The paper's 1.634 conv/s point: paced to the prefiller's exact
    saturation throughput."""
    from repro.traces import TraceConfig, generate_trace
    return generate_trace(n, 1.634, TraceConfig(seed=seed),
                          arrival_process="paced")


def run_system(system: str, trace, *, heterogeneous=False, wrong=0.10,
               slo=None):
    from repro.cluster import paper_deployment
    from repro.core.metrics import summarize
    sim = paper_deployment(system, heterogeneous=heterogeneous,
                           wrong_prediction_rate=wrong)
    sim.submit(trace).run()
    total = sum(c.total_input_tokens + c.total_output_tokens for c in trace)
    return summarize(sim.results(), slo=slo,
                     energy_joules=sim.total_energy_j(),
                     total_tokens=total), sim
