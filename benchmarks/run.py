"""Benchmark harness entrypoint: one function per paper figure/table plus
the roofline analysis over dry-run artifacts.

Usage: PYTHONPATH=src python -m benchmarks.run [--only fig10]
Prints `name,us_per_call,derived` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import figures, roofline
    from .common import emit

    fns = list(figures.ALL)
    if args.only:
        fns = [f for f in fns if args.only in f.__name__]
    failures = 0
    for fn in fns:
        try:
            fn()
        except Exception as e:  # report and continue — partial CSV beats none
            failures += 1
            print(f"BENCH-FAIL {fn.__name__}: {type(e).__name__}: {e}",
                  file=sys.stderr)
            traceback.print_exc(limit=3)
    if not args.only or "roofline" in (args.only or ""):
        try:
            roofline.main(emit=emit)
        except Exception as e:
            failures += 1
            print(f"BENCH-FAIL roofline: {e}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
